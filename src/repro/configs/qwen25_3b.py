"""qwen2.5-3b [dense] — 36L d2048 16H (GQA kv=2) d_ff 11008, vocab 151936,
QKV bias, tied embeddings.  [hf:Qwen/Qwen2.5-0.5B; hf]

kv=2 cannot shard 16 ways -> replicated KV (divisibility fallback).
"""

from .base import ModelConfig


def config():
    return ModelConfig(
        name="qwen2.5-3b", family="dense",
        n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
        d_ff=11008, vocab=151936, head_dim=128,
        qkv_bias=True, tie_embeddings=True,
        rope_theta=1000000.0,
        remat_policy="full", loss_chunk=1024,
    )


def smoke_config():
    return ModelConfig(
        name="qwen25-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, head_dim=16, qkv_bias=True, tie_embeddings=True,
        remat_policy="none", loss_chunk=0,
    )
