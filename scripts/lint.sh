#!/usr/bin/env bash
# Static analysis gate: lint + VMEM verifier + artifact schemas
# (see docs/static_analysis.md).  No kernel execution; seconds, not minutes.
# Usage: scripts/lint.sh [extra repro.analysis args...]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m repro.analysis "$@"
