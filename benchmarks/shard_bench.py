"""Mesh-sharded PCILT decode benchmark -> BENCH_pr3.json.

Standalone on purpose: forcing a host-platform device count requires
``XLA_FLAGS`` to be set *before* jax initializes, so this module pins the
flag at import time and ``benchmarks/run.py`` invokes it as a subprocess
(``shard.*`` section).  Run directly with::

    PYTHONPATH=src python -m benchmarks.shard_bench

Measures, at ``model`` axis sizes 1/2/4/8 over 8 forced host devices:

* **per-device table bytes** — dense ``[G, V, O]`` tables shard the segment
  axis, so each device holds ``G/D`` segments and bytes shrink linearly with
  the model axis (the acceptance criterion for the tensor-parallel decode
  path), plus the ext.-3 sharded pool's padded-local-pool bytes;
* **decode-GEMV latency** — the batch-starved fused path under ``shard_map``
  with its single psum.  On CPU interpret mode this measures dispatch
  plumbing, not TPU kernels; the number seeds the trajectory the TPU tune
  pass will overwrite.
"""

from __future__ import annotations

import json
import os
import time

FORCED_DEVICES = 8
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + f" --xla_force_host_platform_device_count={FORCED_DEVICES}"
    ).strip()

import numpy as np  # noqa: E402

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _timeit(fn, reps=5, warmup=2):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6  # us


def shard_rows(bench_json: str = "BENCH_pr3.json"):
    import jax
    import jax.numpy as jnp
    from repro.core import QuantSpec, calibrate
    from repro.core.serving import convert_kernel
    from repro.launch.mesh import make_decode_mesh

    assert jax.device_count() >= FORCED_DEVICES, (
        f"forced host device count did not apply: {jax.device_count()} "
        f"(XLA_FLAGS must be set before jax initializes)")

    rng = np.random.default_rng(0)
    rows = []
    bytes_per_dev = {}
    pool_bytes_per_dev = {}
    latency_us = {}

    # LM decode-GEMV regime: batch-starved projection, 2-bit codes, g=2.
    bits, group = 2, 2
    spec = QuantSpec(bits)
    B, n, O, X = 8, 1024, 512, 16
    x = jnp.asarray(np.abs(rng.normal(size=(B, n))), jnp.float32)
    w = jnp.asarray(rng.normal(size=(n, O)), jnp.float32)
    cb = rng.normal(size=(X, group, O))
    wc = jnp.asarray(cb[rng.integers(0, X, n // group)].reshape(n, O),
                     jnp.float32)
    s = calibrate(x, spec)

    for model in (1, 2, 4, 8):
        mesh = make_decode_mesh(model)
        lin = convert_kernel(w, spec, s, group, mesh=mesh)
        lsh = convert_kernel(wc, spec, s, group, shared=True, mesh=mesh)
        lin.tune(x)  # local-shard-shape key into the persistent lookup table
        fn = jax.jit(lambda a: lin(a, path="fused"))
        fn(x).block_until_ready()
        t = _timeit(lambda: fn(x).block_until_ready())
        d = str(model)
        bytes_per_dev[d] = lin.per_device_table_bytes()
        pool_bytes_per_dev[d] = lsh.per_device_table_bytes()
        latency_us[d] = t
        rows.append((f"shard.decode_gemv_b{bits}g{group}_{n}x{O}_m{model}", t,
                     f"fused under shard_map, psum over model={model}"))
        rows.append((f"shard.dense_bytes_per_dev_m{model}",
                     bytes_per_dev[d],
                     f"[G/D,V,O] shard, D={lin.shard_count}"))
        rows.append((f"shard.shared_pool_bytes_per_dev_m{model}",
                     pool_bytes_per_dev[d],
                     f"padded local pool, Xmax="
                     f"{lsh.shard_pools.max_cardinality if lsh.shard_pools else X}"))

    base = bytes_per_dev["1"]
    scaling = {d: base / v for d, v in bytes_per_dev.items()}
    rows.append(("shard.dense_bytes_scaling_m8", scaling["8"],
                 "per-device table bytes shrink ~linearly with model axis"))

    payload = {
        "pr": 3,
        "backend": jax.default_backend(),
        "timing": "interpret-mode CPU" if jax.default_backend() != "tpu"
                  else "compiled TPU",
        "forced_host_devices": FORCED_DEVICES,
        "per_device_table_bytes": bytes_per_dev,
        "per_device_shared_pool_bytes": pool_bytes_per_dev,
        "table_bytes_scaling": {k: round(v, 3) for k, v in scaling.items()},
        "decode_gemv_us": {k: round(v, 2) for k, v in latency_us.items()},
        "rows": [
            {"name": name, "us_per_call": round(float(val), 2),
             "derived": derived}
            for name, val, derived in rows
        ],
    }
    if bench_json:
        with open(os.path.join(REPO_ROOT, bench_json), "w") as fp:
            json.dump(payload, fp, indent=1)
    return rows


def main() -> None:
    for name, val, derived in shard_rows():
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
