"""Mesh-sharded PCILT decode benchmark -> BENCH_pr3.json.

Standalone on purpose: forcing a host-platform device count requires
``XLA_FLAGS`` to be set *before* jax initializes, so this module pins the
flag at import time and ``benchmarks/run.py`` invokes it as a subprocess
(``shard.*`` section).  Run directly with::

    PYTHONPATH=src python -m benchmarks.shard_bench

Measures, at ``model`` axis sizes 1/2/4/8 over 8 forced host devices:

* **per-device table bytes** — dense ``[G, V, O]`` tables shard the segment
  axis, so each device holds ``G/D`` segments and bytes shrink linearly with
  the model axis (the acceptance criterion for the tensor-parallel decode
  path), plus the ext.-3 sharded pool's padded-local-pool bytes;
* **decode-GEMV latency** — the batch-starved fused path under ``shard_map``
  with its single psum.  On CPU interpret mode this measures dispatch
  plumbing, not TPU kernels; the number seeds the trajectory the TPU tune
  pass will overwrite.

``--conv-json PATH`` runs the ``shard_conv.*`` section instead (PR 4): the
sharded conv2d with in-VMEM im2col per shard (the conv kernels'
``seg_offset`` parameter) against the reconstructed PR 3 host-im2col +
sharded-GEMV route, at ``--model`` (default 4).  ``benchmarks/run.py``
merges the emitted JSON into BENCH_pr4.json.
"""

from __future__ import annotations

import json
import os
import time

FORCED_DEVICES = 8
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + f" --xla_force_host_platform_device_count={FORCED_DEVICES}"
    ).strip()

import numpy as np  # noqa: E402

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _timeit(fn, reps=5, warmup=2):
    """Median-of-reps microseconds per call (robust to scheduler hiccups on
    shared/throttled CPU runners — see benchmarks/run.py)."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times)) * 1e6  # us


def shard_rows(bench_json: str = "BENCH_pr3.json", smoke: bool = False):
    import jax
    import jax.numpy as jnp
    from repro.core import QuantSpec, calibrate
    from repro.core.serving import convert_kernel
    from repro.launch.mesh import make_decode_mesh

    assert jax.device_count() >= FORCED_DEVICES, (
        f"forced host device count did not apply: {jax.device_count()} "
        f"(XLA_FLAGS must be set before jax initializes)")

    rng = np.random.default_rng(0)
    rows = []
    bytes_per_dev = {}
    pool_bytes_per_dev = {}
    latency_us = {}

    # LM decode-GEMV regime: batch-starved projection, 2-bit codes, g=2.
    bits, group = 2, 2
    spec = QuantSpec(bits)
    B, n, O, X = 8, 1024, 512, 16
    if smoke:
        n, O = 256, 128
    x = jnp.asarray(np.abs(rng.normal(size=(B, n))), jnp.float32)
    w = jnp.asarray(rng.normal(size=(n, O)), jnp.float32)
    cb = rng.normal(size=(X, group, O))
    wc = jnp.asarray(cb[rng.integers(0, X, n // group)].reshape(n, O),
                     jnp.float32)
    s = calibrate(x, spec)

    for model in (1, 8) if smoke else (1, 2, 4, 8):
        mesh = make_decode_mesh(model)
        lin = convert_kernel(w, spec, s, group, mesh=mesh)
        lsh = convert_kernel(wc, spec, s, group, shared=True, mesh=mesh)
        lin.tune(x)  # local-shard-shape key into the persistent lookup table
        fn = jax.jit(lambda a: lin(a, path="fused"))
        fn(x).block_until_ready()
        t = _timeit(lambda: fn(x).block_until_ready(),
                    reps=1 if smoke else 5, warmup=1 if smoke else 2)
        d = str(model)
        bytes_per_dev[d] = lin.per_device_table_bytes()
        pool_bytes_per_dev[d] = lsh.per_device_table_bytes()
        latency_us[d] = t
        rows.append((f"shard.decode_gemv_b{bits}g{group}_{n}x{O}_m{model}", t,
                     f"fused under shard_map, psum over model={model}"))
        rows.append((f"shard.dense_bytes_per_dev_m{model}",
                     bytes_per_dev[d],
                     f"[G/D,V,O] shard, D={lin.shard_count}"))
        rows.append((f"shard.shared_pool_bytes_per_dev_m{model}",
                     pool_bytes_per_dev[d],
                     f"padded local pool, Xmax="
                     f"{lsh.shard_pools.max_cardinality if lsh.shard_pools else X}"))

    base = bytes_per_dev["1"]
    scaling = {d: base / v for d, v in bytes_per_dev.items()}
    rows.append(("shard.dense_bytes_scaling_m8", scaling["8"],
                 "per-device table bytes shrink ~linearly with model axis"))

    payload = {
        "pr": 3,
        "backend": jax.default_backend(),
        "timing": "interpret-mode CPU" if jax.default_backend() != "tpu"
                  else "compiled TPU",
        "smoke": smoke,
        "forced_host_devices": FORCED_DEVICES,
        "per_device_table_bytes": bytes_per_dev,
        "per_device_shared_pool_bytes": pool_bytes_per_dev,
        "table_bytes_scaling": {k: round(v, 3) for k, v in scaling.items()},
        "decode_gemv_us": {k: round(v, 2) for k, v in latency_us.items()},
        "rows": [
            {"name": name, "us_per_call": round(float(val), 2),
             "derived": derived}
            for name, val, derived in rows
        ],
    }
    if bench_json:
        with open(os.path.join(REPO_ROOT, bench_json), "w") as fp:
            json.dump(payload, fp, indent=1)
    return rows


def shard_conv_rows(model: int = 4, smoke: bool = False):
    """Sharded conv2d: the PR 4 in-VMEM-im2col route vs the PR 3 detour.

    Both execute the *same* sharded fused GEMV-or-conv kernels over the same
    ``[G/D, V, O]`` table shards with one psum; the difference is purely
    where the im2col happens — PR 3 extracted patches host-side and fed the
    sharded fused *GEMV*, PR 4 passes the kernels a ``seg_offset`` and
    rebuilds the patch in VMEM per shard.  Returns ``(rows, speedup)``.
    """
    import jax
    import jax.numpy as jnp
    from repro.core import QuantSpec, build_grouped_tables, calibrate
    from repro.core.lut_layers import im2col, pcilt_conv2d, pcilt_linear
    from repro.kernels import ops
    from repro.launch.mesh import make_decode_mesh

    rng = np.random.default_rng(0)
    bits, group = 2, 2
    spec = QuantSpec(bits)
    B, H, W, C, kh, kw, Co = 2, 20, 20, 8, 5, 5, 64
    if smoke:
        B, H, W, Co = 1, 10, 10, 32
    x = jnp.asarray(np.abs(rng.normal(size=(B, H, W, C))), jnp.float32)
    f = jnp.asarray(rng.normal(size=(kh, kw, C, Co)), jnp.float32)
    s = calibrate(x, spec)
    n = kh * kw * C
    G = n // group
    assert G % model == 0, (G, model)
    T = build_grouped_tables(f.reshape(n, Co), spec, s, group)
    mesh = make_decode_mesh(model)
    Gl = G // model

    # Tune both routes' kernels eagerly on the local shard shapes (the shape
    # keys the shard_map traces look up): the conv kernel at local G with a
    # concrete seg_offset, and the GEMV kernel over the patch-row problem.
    ops.pcilt_fused_conv2d(x, T[:Gl], spec, s, group, kh, kw,
                           seg_offset=0, n_total=G * group, autotune=True)
    patches = im2col(x, kh, kw)
    flat = patches.reshape(-1, n)
    ops.pcilt_fused_gemv(flat[:, :Gl * group], T[:Gl], spec, s, group,
                         autotune=True)

    new_route = jax.jit(lambda a: pcilt_conv2d(a, f, spec, s, group,
                                               tables=T, path="fused",
                                               mesh=mesh))

    def _old(a):  # the PR 3 detour, reconstructed: host im2col + sharded GEMV
        p = im2col(a, kh, kw)
        out = pcilt_linear(p, T, spec, s, group, path="fused", mesh=mesh)
        return out

    old_route = jax.jit(_old)
    got, want = new_route(x), old_route(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    got.block_until_ready()
    t_new = _timeit(lambda: new_route(x).block_until_ready(),
                    reps=1 if smoke else 5, warmup=1 if smoke else 2)
    t_old = _timeit(lambda: old_route(x).block_until_ready(),
                    reps=1 if smoke else 5, warmup=1 if smoke else 2)
    tag = f"conv5x5_b{bits}g{group}_{C}to{Co}_m{model}"
    rows = [
        (f"shard_conv.{tag}_host_im2col", t_old,
         "PR3 route: host im2col + sharded fused GEMV"),
        (f"shard_conv.{tag}_in_vmem_im2col", t_new,
         f"{t_old / t_new:.2f}x vs host-im2col route (seg_offset kernels)"),
    ]
    return rows, {f"shard_conv_in_vmem_vs_host_im2col_m{model}":
                  t_old / t_new}


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--conv-json", default=None,
                    help="run the shard_conv section instead of shard.* and "
                         "write rows+speedup JSON to this path")
    ap.add_argument("--model", type=int, default=4)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_pr3.json",
                    help="output JSON for the shard.* section (relative "
                         "paths land at the repo root)")
    args = ap.parse_args(argv)
    if args.conv_json:
        rows, speedup = shard_conv_rows(args.model, smoke=args.smoke)
        with open(args.conv_json, "w") as fp:
            json.dump({
                "speedup": {k: round(v, 3) for k, v in speedup.items()},
                "rows": [{"name": n, "us_per_call": round(float(v), 2),
                          "derived": d} for n, v, d in rows],
            }, fp, indent=1)
        for name, val, derived in rows:
            print(f"{name},{val},{derived}")
        return
    for name, val, derived in shard_rows(args.out, smoke=args.smoke):
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
