"""Traffic-hardened serving benchmark (traffic.* -> BENCH_pr9.json).

Three claims, all on the virtual clock (``runtime.traffic.VirtualClock`` +
``Engine(step_cost_s=...)``) so the sweep is seeded-deterministic and runs
thousands of virtual seconds in real milliseconds:

* **batch-R decode** — the R-aware tuned stacked PCILT path: one decode
  step over R=8 serving slots must beat 8 sequential batch-1 steps on the
  per-slot cache slices by >= 2x (the engine's continuous-batching tick is
  one batched step, not a slot loop — this row is why), and the batched
  logits must match every batch-1 slice **bit-for-bit** (the one-hot table
  contraction and the ssd update are row-independent; any divergence is a
  batching bug, not noise);
* **load sweep** — open-loop Poisson arrivals at 0.5x / 1x / 2x of
  analytic capacity through the bounded-admission engine.  The overload
  contract is asserted inline: at 2x the engine sheds with typed
  ``rejected`` outcomes, outcome counts partition the offered set, and the
  p99 per-token latency of *admitted* requests stays within 2x of the
  0.5x-load p99 (bounded queue => bounded wait — overload degrades
  *throughput for new arrivals*, never the latency of what was admitted);
* **chaos under traffic** — the PR 6 fault schedule injected mid-stream at
  1x load: accounting still partitions, and every request served
  undegraded in both the chaos run and a fault-free reference run of the
  same arrival trace is token-identical ("degraded, never wrong" holds
  under load, not just in the closed-loop smoke).

Violated contracts raise ``AssertionError`` inside the guarded block, which
lands as a skip row — and the CI smoke run (``run.py --smoke``) turns any
skip into a non-zero exit.
"""

from __future__ import annotations

import dataclasses

import numpy as np

#: load sweep points, as multiples of analytic capacity
LOADS = (0.5, 1.0, 2.0)
#: simulated seconds per engine step on the virtual clock
STEP_COST_S = 1e-3
#: mean prompt length drawn by serve._make_requests (uniform 4..11)
PROMPT_MEAN = 7.5


def _capacity(slots: int, max_new: int) -> float:
    """Analytic request/s capacity on the virtual clock.  Prefill ticks are
    *serialized* (one slot replays its prompt at a time) while decode ticks
    are shared by every active slot, so one request costs about
    ``prompt + max_new/slots`` engine ticks of ``STEP_COST_S`` each."""
    return 1.0 / ((PROMPT_MEAN + max_new / slots) * STEP_COST_S)


def _verify(reqs, stats):
    """Bench-side accounting check: ``verify_accounting`` raises SystemExit
    (the CLI smoke's exit path), which would sail *through* run.py's guard
    (it only catches Exception) and kill the whole harness — remap it to
    the AssertionError the guard turns into a failing skip row."""
    from repro.launch.serve import verify_accounting

    try:
        verify_accounting(reqs, stats)
    except SystemExit as e:
        raise AssertionError(str(e)) from None


def _mamba_cfg(smoke: bool):
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.configs.base import PCILTConfig

    cfg = get_smoke_config("mamba2-130m")
    return dataclasses.replace(cfg, pcilt=PCILTConfig(act_bits=2, group=2),
                               dtype=jnp.float32)


def _slice_slot(cache, i: int, slots: int):
    """One slot's view of the engine cache: layer-stacked leaves carry the
    slot axis at position 1 (``Engine._reset_slot``'s predicate)."""
    import jax

    def s(a):
        if hasattr(a, "ndim") and a.ndim >= 2 and a.shape[1] == slots:
            return a[:, i:i + 1]
        return a

    return dict(cache, layers=jax.tree.map(s, cache["layers"]))


def batch_r_block(rows, speedups, timeit, smoke: bool):
    """One R=8 tuned stacked step vs 8 sequential batch-1 steps."""
    import jax
    import jax.numpy as jnp

    from repro.core.serving import convert_mamba_decode
    from repro.models import build_model
    from repro.nn import materialize
    from repro.nn.layers import Ctx

    R = 8
    cfg = _mamba_cfg(smoke)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = materialize(model.param_specs(), key)
    ctx = Ctx()
    calib = jax.random.randint(key, (R, 16), 0, cfg.vocab)
    _, cache = model.prefill(params, {"tokens": calib}, ctx)
    toks = jax.random.randint(key, (R, 1), 0, cfg.vocab)

    eng = convert_mamba_decode(model, params, calib)
    eng.tune(batch=(1, R))  # R is a tuned axis: winners for both regimes

    step = jax.jit(lambda p, c, t: model.decode_step(p, c, t, ctx,
                                                     pcilt=eng.pcilt))
    logits_r, _ = step(params, cache, toks)
    logits_r.block_until_ready()
    t_r = timeit(lambda: step(params, cache, toks)[0].block_until_ready())

    slot0 = _slice_slot(cache, 0, R)
    step(params, slot0, toks[0:1])[0].block_until_ready()  # warm B=1 trace
    t_1 = timeit(lambda: step(params, slot0, toks[0:1])[0]
                 .block_until_ready())

    # bit-exactness: the batched step's row i must equal the batch-1 step
    # on slot i's cache slice, bitwise (row-independent table contraction)
    for i in range(R):
        li, _ = step(params, _slice_slot(cache, i, R), toks[i:i + 1])
        if not bool(jnp.all(li[0] == logits_r[i])):
            bad = int(jnp.sum(li[0] != logits_r[i]))
            raise AssertionError(
                f"batch-R decode is not bit-exact per slot: slot {i} "
                f"diverges in {bad} logit(s) from its batch-1 slice")

    speedup = (R * t_1) / t_r
    speedups["batch_r8_vs_loop"] = speedup
    tag = f"d{cfg.d_model}_L{cfg.n_layers}"
    rows.append((f"traffic.batch_r8_{tag}_step", t_r,
                 f"{R / (t_r / 1e6):.1f} tokens/s, one tuned R=8 step"))
    rows.append((f"traffic.batch_r8_{tag}_loop8_step", t_1,
                 "one batch-1 step on a slot slice (x8 for the loop)"))
    rows.append((f"traffic.batch_r8_{tag}_speedup", 0.0,
                 f"{speedup:.2f}x vs 8 sequential batch-1 steps "
                 f"(bit-exact per slot)"))
    if speedup < 2.0:
        raise AssertionError(
            f"batch-R target missed: R=8 step is {speedup:.2f}x vs the "
            f"batch-1 loop (need >= 2x)")


def _run_load(cfg, load: float, n: int, slots: int, max_new: int, seed: int):
    """One open-loop run at ``load`` x capacity; returns the traffic row."""
    from repro.launch.serve import (Engine, _make_requests, token_latencies)
    from repro.runtime import VirtualClock, poisson_arrivals

    eng = Engine(cfg, max_len=64, slots=slots, clock=VirtualClock(),
                 step_cost_s=STEP_COST_S, queue_limit=slots // 2)
    reqs = _make_requests(cfg, n, max_new, None, seed)
    arrivals = poisson_arrivals(n, load * _capacity(slots, max_new),
                                seed=seed)
    stats = eng.run_traffic(reqs, arrivals)
    _verify(reqs, stats)
    lats = token_latencies(reqs)
    toks = sum(len(r.out) for r in reqs if r.outcome in ("served", "degraded"))
    return {
        "profile": "poisson",
        "load": load,
        "offered": stats["offered"],
        "served": stats["served"],
        "degraded": stats["degraded"],
        "failed": stats["failed"],
        "rejected": stats["rejected"],
        "shed_rate": round(stats["shed_rate"], 4),
        "p50_token_s": (round(float(np.percentile(lats, 50)), 6)
                        if lats else None),
        "p99_token_s": (round(float(np.percentile(lats, 99)), 6)
                        if lats else None),
        "tokens_per_s": (round(toks / stats["wall_s"], 2)
                         if stats["wall_s"] > 0 else None),
    }


def load_sweep_block(rows, traffic, smoke: bool):
    """Poisson arrivals at 0.5x/1x/2x capacity; assert the overload
    contract inline."""
    from repro.configs import get_smoke_config

    cfg = get_smoke_config("qwen3-0.6b")
    slots, max_new = 4, 8
    n = 16 if smoke else 48
    for load in LOADS:
        row = _run_load(cfg, load, n, slots, max_new, seed=9)
        traffic.append(row)
        lat = (f"p50/p99 {row['p50_token_s']}/{row['p99_token_s']} s/token"
               if row["p99_token_s"] is not None else "no completions")
        rows.append((
            f"traffic.poisson_{load}x_offered{row['offered']}", 0.0,
            f"{row['served']} served / {row['degraded']} degraded / "
            f"{row['failed']} failed / {row['rejected']} rejected "
            f"(shed {100 * row['shed_rate']:.1f}%), "
            f"{row['tokens_per_s']} tokens/s, {lat}"))

    over = next(r for r in traffic if r["load"] == 2.0)
    base = next(r for r in traffic if r["load"] == 0.5)
    if over["rejected"] == 0:
        raise AssertionError(
            "overload contract: 2x load shed nothing — bounded admission "
            "never engaged (capacity estimate or queue limit is off)")
    if base["p99_token_s"] and over["p99_token_s"]:
        ratio = over["p99_token_s"] / base["p99_token_s"]
        if ratio > 2.0:
            raise AssertionError(
                f"overload contract: admitted p99 per-token latency grew "
                f"{ratio:.2f}x from 0.5x to 2x load (bounded queue must "
                f"hold it within 2x)")


def chaos_traffic_block(rows, smoke: bool):
    """PR 6 fault schedule under 1x open-loop traffic: degraded, never
    wrong — and never unaccounted — while overloadable."""
    from repro.launch.serve import Engine, _chaos_plan, _make_requests
    from repro.runtime import VirtualClock, poisson_arrivals
    from repro.runtime.faults import FaultInjector

    cfg = _mamba_cfg(smoke)
    slots, max_new, n, seed = 2, 6, 12, 9
    # under-capacity on purpose: restarts/rollbacks *consume virtual time*
    # (replayed steps re-advance the clock), and the stream must outlive the
    # fault window so late requests run clean
    arrivals = poisson_arrivals(n, 0.6 * _capacity(slots, max_new),
                                seed=seed)

    def make(chaos: bool):
        eng = Engine(cfg, max_len=64, slots=slots, pcilt=True,
                     clock=VirtualClock(), step_cost_s=STEP_COST_S,
                     queue_limit=2 * slots)
        if chaos:
            injector = FaultInjector(fail_at=(7,), seed=seed)
            plan = _chaos_plan(eng, injector)
            # keep the PR 6 transient faults (garbled cache / injected
            # fail / NaN poison) on their early steps, but push the two
            # *permanent* table corruptions past the first completions:
            # demotion is forever (the tables really are corrupt), so with
            # open-loop arrivals nothing served after them is undegraded —
            # the token-identity comparison needs clean completions first
            plan[60] = plan.pop(15)  # corrupt_proj
            plan[68] = plan.pop(19)  # flip_head
            eng.chaos = plan
            eng._injector = injector
        reqs = _make_requests(cfg, n, max_new, None, seed)
        stats = eng.run_traffic(reqs, arrivals)
        _verify(reqs, stats)
        return eng, reqs, stats

    eng_c, reqs_c, stats_c = make(chaos=True)
    if not eng_c._injector.events:
        raise AssertionError("chaos-under-traffic injected no faults")
    _, reqs_f, _ = make(chaos=False)
    both = [(r, q) for r, q in zip(reqs_c, reqs_f)
            if r.outcome == "served" and q.outcome == "served"]
    mismatched = [r.rid for r, q in both if r.out != q.out]
    if mismatched:
        raise AssertionError(
            f"chaos-under-traffic: undegraded tokens diverge from the "
            f"fault-free trace for requests {mismatched}")
    if not both:
        raise AssertionError(
            "chaos-under-traffic: no request served undegraded in both "
            "runs — the token-identity check compared nothing")
    n_exact = len(both)
    rows.append((
        "traffic.chaos_1x_contract", 0.0,
        f"{stats_c['offered']} offered -> {stats_c['served']} served / "
        f"{stats_c['degraded']} degraded / {stats_c['failed']} failed / "
        f"{stats_c['rejected']} rejected; "
        f"{len(eng_c._injector.events)} faults, "
        f"{stats_c['restarts']} restarts, {stats_c['rollbacks']} rollbacks; "
        f"{n_exact} token-identical to fault-free trace"))


def collect(bench_json, smoke: bool, timeit, guard, json_rows):
    """Run all three blocks and (optionally) write the BENCH payload.
    Harness helpers are injected by ``run.py`` so smoke reps / skip
    bookkeeping stay identical across sections."""
    import json as _json
    import logging

    import jax

    # shedding/breach warnings are the *expected* behavior under test here —
    # keep the CSV harness output readable
    logging.getLogger("repro").setLevel(logging.ERROR)

    rows = []
    speedups = {}
    skipped = {}
    traffic = []

    guard(rows, skipped, "traffic.batch_r8",
          lambda: batch_r_block(rows, speedups, timeit, smoke))
    guard(rows, skipped, "traffic.load_sweep",
          lambda: load_sweep_block(rows, traffic, smoke))
    guard(rows, skipped, "traffic.chaos_1x",
          lambda: chaos_traffic_block(rows, smoke))

    if bench_json:
        payload = {
            "pr": 9,
            "backend": jax.default_backend(),
            "timing": "interpret-mode CPU" if jax.default_backend() != "tpu"
                      else "compiled TPU",
            "target_min_speedup": {"batch_r8_vs_loop": 2.0},
            "speedup": {k: round(v, 3) for k, v in speedups.items()},
            "skipped": skipped,
            "rows": json_rows(rows),
        }
        if traffic:
            payload["traffic"] = traffic
        with open(bench_json, "w") as fp:
            _json.dump(payload, fp, indent=1)
    return rows
