"""Reproduction of the paper's quantitative claims.

One function per claim (the paper has no numbered tables; its quantitative
content is in §Basic Version, §Using Shared PCILTs and ref. [73]):

  C1  table-build overhead: 6,400 multiplies to build 5×5/INT8 tables vs
      1.9482e11 DM multiplies for 10k 1024×768 inferences;
  C2  PCILT memory for the 50-80-120-200-350 CNN: ~1.65 GB (INT8),
      ~100 MB (INT4), ~75 MB (INT4 + narrow product cells);
  C3  shared-PCILT memory: weight actual-cardinality 32, INT10+INT16
      activations: ~25 MB, ~18 MB nested — for an arbitrarily large CNN;
  C4  BoolHash [73]: 8 boolean activations per 8-bit offset -> 6.59×
      speedup.  We report the op-count ratio and our own CPU wall-clock for
      the same configuration (hardware-honest per DESIGN.md §10.4).

Each returns (name, value, paper_value, note) rows.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (
    QuantSpec, build_cost_multiplies, table_bytes, grouped_table_bytes,
    shared_table_bytes,
)
from repro.models.cnn import PAPER_CHANNELS, PAPER_FILTER

MB = 1e6
GB = 1e9


def _paper_cnn_weights(in_channels: int = 1) -> int:
    n, cin = 0, in_channels
    for cout in PAPER_CHANNELS:
        n += PAPER_FILTER * PAPER_FILTER * cin * cout
        cin = cout
    return n


def claim_build_overhead():
    """C1: one 5×5 filter, INT8 activations — build cost vs inference cost."""
    build = build_cost_multiplies(5 * 5, 8)  # one input channel, per paper
    dm = 10_000 * 1024 * 768 * 25  # 10k samples, 5x5 filter at every pixel
    return [
        ("C1_build_multiplies", build, 6_400, "5x5 filter x 256 act values"),
        ("C1_dm_multiplies", dm, 1.9482e11, "10k 1024x768 samples, 5x5 DM"),
        ("C1_overhead_ratio", build / dm, 6400 / 1.9482e11,
         "build cost is negligible (paper §Basic Version)"),
    ]


def claim_cnn_memory():
    """C2: the paper's example CNN under three activation regimes."""
    n_w = _paper_cnn_weights()
    int8 = table_bytes(n_w, 8, 2)          # 16-bit product cells
    int4 = table_bytes(n_w, 4, 2)
    int4_narrow = table_bytes(n_w, 4, 2) * 12 // 16  # 12-bit product cells
    return [
        ("C2_weights", n_w, None, "5 conv layers 50-80-120-200-350, 5x5"),
        ("C2_int8_bytes", int8, 1.65 * GB,
         f"ours {int8/GB:.2f} GB vs paper ~1.65 GB (value-size assumptions)"),
        ("C2_int4_bytes", int4, 100 * MB,
         f"ours {int4/MB:.0f} MB vs paper ~100 MB"),
        ("C2_int4_narrow_bytes", int4_narrow, 75 * MB,
         f"ours {int4_narrow/MB:.0f} MB vs paper ~75 MB"),
        ("C2_int8_over_int4", int8 / int4, 256 / 16,
         "cardinality ratio reproduces exactly"),
    ]


def claim_shared_tables():
    """C3: shared-PCILT memory is CNN-size-independent."""
    flat = shared_table_bytes(32, [10, 16], 4)
    nested = shared_table_bytes(32, [10, 16], 4, nested=True)
    return [
        ("C3_shared_bytes", flat, 25 * MB,
         f"ours {flat/MB:.1f} MB vs paper ~25 MB (INT32 cells assumed)"),
        ("C3_nested_bytes", nested, 18 * MB,
         f"ours {nested/MB:.1f} MB vs paper ~18 MB"),
        ("C3_size_independent", 1.0, 1.0,
         "holds for an arbitrarily big CNN — table count depends only on "
         "weight actual-cardinality x activation cardinalities"),
    ]


def claim_boolhash(reps: int = 5):
    """C4: boolean activations, 8 per offset — op ratio + measured CPU time.

    Paper's [73] reports 6.59x on their CPU.  Ideal op-count ratio is 8x
    (one fetch+add replaces 8 MAC pairs); offset packing overhead eats part
    of it.  We measure our numpy gather path against a float32 DM dot.
    """
    rng = np.random.default_rng(0)
    n, out, batch = 4096, 256, 512
    g = 8
    acts_bool = (rng.random((batch, n)) > 0.5)
    w = rng.normal(size=(n, out)).astype(np.float32)

    # DM baselines: (a) float32 BLAS (strongest possible CPU baseline);
    # (b) integer DM — the paper's [73] setting (integer MAC hardware/code
    # path, no BLAS).  numpy integer matmul takes the generic inner loop.
    a_f = acts_bool.astype(np.float32)
    t0 = time.perf_counter()
    for _ in range(reps):
        dm = a_f @ w
    t_dm = (time.perf_counter() - t0) / reps

    w_i = np.round(w * 16).astype(np.int32)
    a_i = acts_bool.astype(np.int32)
    t0 = time.perf_counter()
    for _ in range(max(reps // 2, 1)):
        dm_i = a_i @ w_i
    t_dm_int = (time.perf_counter() - t0) / max(reps // 2, 1)

    # PCILT: pre-packed offsets (paper: separate pre-processing circuitry,
    # reused across filters) + table row gather + segment-sum
    shifts = (1 << np.arange(g)).astype(np.int64)
    tables = np.zeros((n // g, 256, out), np.float32)
    grid = ((np.arange(256)[:, None] >> np.arange(g)[None]) & 1).astype(np.float32)
    for s in range(n // g):
        tables[s] = grid @ w[s * g : (s + 1) * g]
    offs = (acts_bool.reshape(batch, n // g, g) @ shifts).astype(np.int32)
    t0 = time.perf_counter()
    for _ in range(reps):
        picked = tables[np.arange(n // g)[None, :], offs]  # [B, n/g, out]
        lut = picked.sum(axis=1)
    t_lut = (time.perf_counter() - t0) / reps
    np.testing.assert_allclose(lut, dm, rtol=1e-4, atol=1e-3)

    # also verify against the integer DM
    np.testing.assert_allclose(
        (acts_bool.astype(np.float32) @ (w_i.astype(np.float32))), dm_i,
        rtol=1e-5)

    ops_dm = 2 * batch * n * out
    ops_lut = batch * (n // g) * out * 2  # fetch-add per segment (+ pack, amortized)
    return [
        ("C4_op_ratio", ops_dm / ops_lut, 6.59,
         "ideal 8x; paper measured 6.59x with packing overhead"),
        ("C4_dm_blas_us", t_dm * 1e6, None, "float32 BLAS matmul baseline"),
        ("C4_dm_int_us", t_dm_int * 1e6, None,
         "integer DM (the paper's [73] no-BLAS setting)"),
        ("C4_lut_us", t_lut * 1e6, None, "numpy gather+sum PCILT path"),
        ("C4_ratio_vs_int_dm", t_dm_int / t_lut, 6.59,
         "PCILT vs integer DM — the paper's comparison"),
        ("C4_ratio_vs_blas", t_dm / t_lut, None,
         "vs BLAS (hardware-honest; DESIGN §2 — LUT wins on fetch-dominated "
         "hardware, multiply-rich units differ)"),
    ]


def all_claims():
    rows = []
    for fn in (claim_build_overhead, claim_cnn_memory, claim_shared_tables,
               claim_boolhash):
        rows.extend(fn())
    return rows


if __name__ == "__main__":
    for name, ours, paper, note in all_claims():
        p = "-" if paper is None else f"{paper:.4g}"
        print(f"{name:28s} ours={ours:.6g} paper={p:10s} {note}")
