"""Benchmark harness.  Prints ``name,us_per_call,derived`` CSV.

Sections:
  paper.*    — the paper's quantitative claims (benchmarks/paper_claims.py);
               derived = paper's own value where it states one.
  micro.*    — CPU microbenchmarks of the PCILT fetch paths vs direct
               multiplication at several shapes/cardinalities.
  lm.*       — PCILT decode-projection table memory for the assigned archs
               (the paper's memory feasibility analysis applied to the zoo).
  fused.*    — host-packed vs fused Pallas pipelines (quantize→pack→fetch in
               VMEM, repro.kernels.pcilt_fused) at the paper's 5x5-conv shape
               and the LM decode-GEMV regime; the fused path is autotuned
               once through the persistent tile lookup table first.  Results
               are also written to BENCH_pr1.json at the repo root to seed
               the per-PR perf trajectory.
  shared.*   — the extension-3 shared-pool fused path
               (repro.kernels.pcilt_shared) vs the pointer-gather reference
               and the dense fused path, on weight-clustered layers at the
               same two regimes, plus the pool-vs-dense table-memory ratio.
               Results are written to BENCH_pr2.json.
  shard.*    — mesh-sharded tables for tensor-parallel decode
               (benchmarks/shard_bench.py, run as a subprocess because the
               forced host-device count must be set before jax initializes):
               per-device table bytes and decode-GEMV latency at
               model=1/2/4/8 over 8 forced host devices.  Results are
               written to BENCH_pr3.json.
  roofline.* — summary terms per hillclimbed cell (full table:
               ``python -m benchmarks.roofline``).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _timeit(fn, reps=5, warmup=2):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6  # us


def paper_rows():
    from benchmarks.paper_claims import all_claims

    out = []
    for name, ours, paper, _ in all_claims():
        out.append((f"paper.{name}", ours, paper if paper is not None else ""))
    return out


def micro_rows():
    import jax
    import jax.numpy as jnp
    from repro.core import (QuantSpec, calibrate, build_grouped_tables,
                            pcilt_linear, quantize, dequantize)

    rows = []
    rng = np.random.default_rng(0)
    for (bits, group, n, out, batch) in [(1, 8, 2048, 256, 256),
                                         (2, 4, 2048, 256, 256),
                                         (4, 2, 1024, 256, 256)]:
        spec = QuantSpec(bits)
        x = jnp.asarray(np.abs(rng.normal(size=(batch, n))), jnp.float32)
        w = jnp.asarray(rng.normal(size=(n, out)), jnp.float32)
        s = calibrate(x, spec)
        T = build_grouped_tables(w, spec, s, group)
        xq = dequantize(quantize(x, spec, s), spec, s)

        dm = jax.jit(lambda xq, w: xq @ w)
        ga = jax.jit(lambda x, T: pcilt_linear(x, T, spec, s, group, path="gather"))
        oh = jax.jit(lambda x, T: pcilt_linear(x, T, spec, s, group, path="onehot"))
        dm(xq, w).block_until_ready()
        t_dm = _timeit(lambda: dm(xq, w).block_until_ready())
        t_ga = _timeit(lambda: ga(x, T).block_until_ready())
        t_oh = _timeit(lambda: oh(x, T).block_until_ready())
        tag = f"b{bits}g{group}_{n}x{out}"
        rows.append((f"micro.dm_{tag}", t_dm, ""))
        rows.append((f"micro.lut_gather_{tag}", t_ga, f"{t_dm/t_ga:.2f}x vs dm"))
        rows.append((f"micro.lut_onehot_{tag}", t_oh, f"{t_dm/t_oh:.2f}x vs dm"))
    return rows


def lm_rows():
    from repro.configs import ARCHS, get_config
    from repro.core.serving import mlp_table_bytes

    rows = []
    for arch in ARCHS:
        cfg = get_config(arch)
        if not cfg.d_ff:
            continue
        b = mlp_table_bytes(cfg.d_model, cfg.d_ff, act_bits=4, group=2)
        rows.append((f"lm.mlp_tables_{arch}", b / 2**20,
                     "MiB/layer @INT4 g=2 — why ext.3 sharing matters"))
    return rows


def fused_rows(bench_json: str = "BENCH_pr1.json"):
    import jax
    import jax.numpy as jnp
    from repro.core import QuantSpec, calibrate, build_grouped_tables, pcilt_linear
    from repro.core.lut_layers import pcilt_conv2d
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    rows = []
    speedups = {}

    # --- LM decode-GEMV regime: batch-starved projection [n -> O] ---------
    bits, group = 2, 2
    spec = QuantSpec(bits)
    B, n, O = 8, 1024, 1024
    x = jnp.asarray(np.abs(rng.normal(size=(B, n))), jnp.float32)
    w = jnp.asarray(rng.normal(size=(n, O)), jnp.float32)
    s = calibrate(x, spec)
    T = build_grouped_tables(w, spec, s, group)
    # tune-once-and-record through the persistent lookup table; the jitted
    # dispatch below then hits the cache at trace time (zero-cost lookup).
    ops.pcilt_fused_gemv(x, T, spec, s, group, autotune=True)
    host = jax.jit(lambda x: pcilt_linear(x, T, spec, s, group, path="kernel"))
    fused = jax.jit(lambda x: pcilt_linear(x, T, spec, s, group, path="fused"))
    host(x).block_until_ready()
    fused(x).block_until_ready()
    t_host = _timeit(lambda: host(x).block_until_ready())
    t_fused = _timeit(lambda: fused(x).block_until_ready())
    speedups["decode_gemv"] = t_host / t_fused
    tag = f"decode_b{bits}g{group}_{n}x{O}"
    rows.append((f"fused.{tag}_hostpacked", t_host, ""))
    rows.append((f"fused.{tag}_fused", t_fused,
                 f"{t_host / t_fused:.2f}x vs host-packed kernel"))

    Tb = T.astype(jnp.bfloat16)
    ops.pcilt_fused_gemv(x, Tb, spec, s, group, autotune=True)
    fused_b = jax.jit(lambda x: pcilt_linear(x, Tb, spec, s, group, path="fused"))
    fused_b(x).block_until_ready()
    t_fused_b = _timeit(lambda: fused_b(x).block_until_ready())
    speedups["decode_gemv_bf16"] = t_host / t_fused_b
    rows.append((f"fused.{tag}_fused_bf16tab", t_fused_b,
                 f"{t_host / t_fused_b:.2f}x vs host-packed kernel"))

    # --- the paper's conv regime: 5x5 filter, small image, low-bit codes --
    B, H, W, C, kh, kw, Co = 2, 14, 14, 8, 5, 5, 16
    xc = jnp.asarray(np.abs(rng.normal(size=(B, H, W, C))), jnp.float32)
    f = jnp.asarray(rng.normal(size=(kh, kw, C, Co)), jnp.float32)
    sc = calibrate(xc, spec)
    nf = kh * kw * C
    Tc = build_grouped_tables(f.reshape(nf, Co), spec, sc, group)
    ops.pcilt_fused_conv2d(xc, Tc, spec, sc, group, kh, kw, autotune=True)
    hostc = jax.jit(lambda x: pcilt_conv2d(x, f, spec, sc, group, path="kernel"))
    fusedc = jax.jit(lambda x: pcilt_conv2d(x, f, spec, sc, group, path="fused"))
    hostc(xc).block_until_ready()
    fusedc(xc).block_until_ready()
    t_hostc = _timeit(lambda: hostc(xc).block_until_ready())
    t_fusedc = _timeit(lambda: fusedc(xc).block_until_ready())
    speedups["conv5x5"] = t_hostc / t_fusedc
    tagc = f"conv5x5_b{bits}g{group}_{C}to{Co}"
    rows.append((f"fused.{tagc}_hostpacked", t_hostc, ""))
    rows.append((f"fused.{tagc}_fused", t_fusedc,
                 f"{t_hostc / t_fusedc:.2f}x vs host-packed kernel"))

    if bench_json:
        payload = {
            "pr": 1,
            "backend": jax.default_backend(),
            "timing": "interpret-mode CPU" if jax.default_backend() != "tpu"
                      else "compiled TPU",
            "target_min_speedup": 1.3,
            "speedup": {k: round(v, 3) for k, v in speedups.items()},
            "rows": [
                {"name": name, "us_per_call": round(us, 2), "derived": derived}
                for name, us, derived in rows
            ],
        }
        with open(os.path.join(REPO_ROOT, bench_json), "w") as fp:
            json.dump(payload, fp, indent=1)
    return rows


def shared_rows(bench_json: str = "BENCH_pr2.json"):
    import jax
    import jax.numpy as jnp
    from repro.core import (QuantSpec, calibrate, build_shared_grouped_tables,
                            pcilt_linear)
    from repro.core.lut_layers import pcilt_conv2d
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    rows = []
    speedups = {}
    ratios = {}

    def codebook_weights(n, O, group, X):
        # Weight-clustered / palettized regime (the ext.-3 precondition):
        # [group, O] segments drawn from an X-entry codebook.
        G = n // group
        cb = rng.normal(size=(X, group, O))
        return jnp.asarray(cb[rng.integers(0, X, G)].reshape(n, O),
                           jnp.float32)

    # --- LM decode-GEMV regime over a weight-clustered projection ---------
    bits, group = 2, 2
    spec = QuantSpec(bits)
    B, n, O, X = 8, 1024, 1024, 16
    x = jnp.asarray(np.abs(rng.normal(size=(B, n))), jnp.float32)
    w = codebook_weights(n, O, group, X)
    s = calibrate(x, spec)
    st = build_shared_grouped_tables(w, spec, s, group)
    T = st.materialize()  # dense [G, V, O] (for the dense-fused comparison)
    ops.pcilt_shared_gemv(x, st.pool, st.seg_idx, spec, s, group,
                          autotune=True)
    ops.pcilt_fused_gemv(x, T, spec, s, group, autotune=True)
    ga = jax.jit(lambda x: pcilt_linear(x, st, spec, s, group, path="gather"))
    sh = jax.jit(lambda x: pcilt_linear(x, st, spec, s, group, path="shared"))
    fu = jax.jit(lambda x: pcilt_linear(x, T, spec, s, group, path="fused"))
    for f in (ga, sh, fu):
        f(x).block_until_ready()
    t_ga = _timeit(lambda: ga(x).block_until_ready())
    t_sh = _timeit(lambda: sh(x).block_until_ready())
    t_fu = _timeit(lambda: fu(x).block_until_ready())
    speedups["decode_gemv_vs_gather"] = t_ga / t_sh
    speedups["decode_gemv_vs_dense_fused"] = t_fu / t_sh
    ratios["decode_gemv_table_mem"] = st.dedup_ratio
    tag = f"decode_b{bits}g{group}_{n}x{O}_X{st.pool_cardinality}"
    rows.append((f"shared.{tag}_gather", t_ga, ""))
    rows.append((f"shared.{tag}_dense_fused", t_fu, ""))
    rows.append((f"shared.{tag}_fused_shared", t_sh,
                 f"{t_ga / t_sh:.2f}x vs gather, {t_fu / t_sh:.2f}x vs "
                 f"dense-fused"))
    rows.append((f"shared.{tag}_table_mem_ratio", st.dedup_ratio,
                 f"dense {st.dense_bytes()/2**20:.1f} MiB -> pool "
                 f"{st.pool_bytes()/2**20:.2f} MiB"))

    # --- the paper's conv regime: 5x5 filter, weight-clustered.  Co=64 (a
    # realistic channel width) is where the pooled X*V-lane contraction pulls
    # clear of both the gather and the dense Gb*V-lane fused contraction. ---
    B, H, W, C, kh, kw, Co, Xc = 2, 14, 14, 8, 5, 5, 64, 8
    xc = jnp.asarray(np.abs(rng.normal(size=(B, H, W, C))), jnp.float32)
    nf = kh * kw * C
    wc = codebook_weights(nf, Co, group, Xc)
    f = jnp.asarray(np.asarray(wc).reshape(kh, kw, C, Co), jnp.float32)
    sc = calibrate(xc, spec)
    stc = build_shared_grouped_tables(wc, spec, sc, group)
    Tc = stc.materialize()
    ops.pcilt_shared_conv2d(xc, stc.pool, stc.seg_idx, spec, sc, group,
                            kh, kw, autotune=True)
    ops.pcilt_fused_conv2d(xc, Tc, spec, sc, group, kh, kw, autotune=True)
    gac = jax.jit(lambda x: pcilt_conv2d(x, f, spec, sc, group, tables=stc,
                                         path="gather"))
    shc = jax.jit(lambda x: pcilt_conv2d(x, f, spec, sc, group, tables=stc,
                                         path="shared"))
    fuc = jax.jit(lambda x: pcilt_conv2d(x, f, spec, sc, group, tables=Tc,
                                         path="fused"))
    for fn in (gac, shc, fuc):
        fn(xc).block_until_ready()
    t_gac = _timeit(lambda: gac(xc).block_until_ready())
    t_shc = _timeit(lambda: shc(xc).block_until_ready())
    t_fuc = _timeit(lambda: fuc(xc).block_until_ready())
    speedups["conv5x5_vs_gather"] = t_gac / t_shc
    speedups["conv5x5_vs_dense_fused"] = t_fuc / t_shc
    ratios["conv5x5_table_mem"] = stc.dedup_ratio
    tagc = f"conv5x5_b{bits}g{group}_{C}to{Co}_X{stc.pool_cardinality}"
    rows.append((f"shared.{tagc}_gather", t_gac, ""))
    rows.append((f"shared.{tagc}_dense_fused", t_fuc, ""))
    rows.append((f"shared.{tagc}_fused_shared", t_shc,
                 f"{t_gac / t_shc:.2f}x vs gather, {t_fuc / t_shc:.2f}x vs "
                 f"dense-fused"))
    rows.append((f"shared.{tagc}_table_mem_ratio", stc.dedup_ratio,
                 f"dense {stc.dense_bytes()/2**10:.0f} KiB -> pool "
                 f"{stc.pool_bytes()/2**10:.0f} KiB"))

    if bench_json:
        payload = {
            "pr": 2,
            "backend": jax.default_backend(),
            "timing": "interpret-mode CPU" if jax.default_backend() != "tpu"
                      else "compiled TPU",
            "target_min_speedup": 1.0,
            "speedup": {k: round(v, 3) for k, v in speedups.items()},
            "table_mem_ratio": {k: round(v, 3) for k, v in ratios.items()},
            "rows": [
                {"name": name, "us_per_call": round(us, 2), "derived": derived}
                for name, us, derived in rows
            ],
        }
        with open(os.path.join(REPO_ROOT, bench_json), "w") as fp:
            json.dump(payload, fp, indent=1)
    return rows


def shard_rows(bench_json: str = "BENCH_pr3.json"):
    """Run benchmarks/shard_bench.py in a subprocess (it must force the host
    device count before jax initializes — this process has usually already
    initialized jax on 1 device) and relay the rows it recorded."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    try:
        r = subprocess.run(
            [sys.executable, "-m", "benchmarks.shard_bench"],
            cwd=REPO_ROOT, env=env, capture_output=True, text=True,
            timeout=1800,
        )
    except subprocess.TimeoutExpired:
        return [("shard.error", 0.0, "shard_bench timed out after 1800s")]
    if r.returncode != 0:
        lines = (r.stderr or r.stdout).strip().splitlines()
        detail = lines[-1][:120] if lines else f"exit code {r.returncode}"
        return [("shard.error", 0.0, detail)]
    payload = json.load(open(os.path.join(REPO_ROOT, bench_json)))
    return [(row["name"], row["us_per_call"], row["derived"])
            for row in payload["rows"]]


def roofline_rows():
    import glob
    import json
    import os
    from benchmarks.roofline import terms, DRYRUN_DIR

    rows = []
    targets = [
        ("llama4-maverick-400b-a17b", "train_4k", "pod16x16"),
        ("qwen3-0.6b", "train_4k", "pod16x16"),
        ("granite-moe-3b-a800m", "decode_32k", "pod16x16"),
    ]
    for arch, shape, mesh in targets:
        safe = arch.replace(".", "_")
        p = os.path.join(DRYRUN_DIR, f"{safe}__{shape}__{mesh}.json")
        if not os.path.exists(p):
            continue
        c = json.load(open(p))
        if c["status"] != "ok":
            continue
        t_c, t_m, t_k, dom, frac, useful = terms(c)
        rows.append((f"roofline.{arch}.{shape}.step_s",
                     (max(t_c, t_m, t_k)) * 1e6,
                     f"dom={dom} frac={frac:.3f} useful={useful:.3f}"))
    return rows


def main() -> None:
    print("name,us_per_call,derived")
    for section in (paper_rows, micro_rows, lm_rows, fused_rows, shared_rows,
                    shard_rows, roofline_rows):
        for name, val, derived in section():
            print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
