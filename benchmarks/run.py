"""Benchmark harness.  Prints ``name,us_per_call,derived`` CSV.

Sections:
  paper.*    — the paper's quantitative claims (benchmarks/paper_claims.py);
               derived = paper's own value where it states one.
  micro.*    — CPU microbenchmarks of the PCILT fetch paths vs direct
               multiplication at several shapes/cardinalities.
  lm.*       — PCILT decode-projection table memory for the assigned archs
               (the paper's memory feasibility analysis applied to the zoo).
  fused.*    — host-packed vs fused Pallas pipelines (quantize→pack→fetch in
               VMEM, repro.kernels.pcilt_fused) at the paper's 5x5-conv shape
               and the LM decode-GEMV regime; the fused path is autotuned
               once through the persistent tile lookup table first.  Results
               are also written to BENCH_pr1.json at the repo root to seed
               the per-PR perf trajectory.
  shared.*   — the extension-3 shared-pool fused path
               (repro.kernels.pcilt_shared) vs the pointer-gather reference
               and the dense fused path, on weight-clustered layers at the
               same two regimes, plus the pool-vs-dense table-memory ratio.
               Results are written to BENCH_pr2.json.
  shard.*    — mesh-sharded tables for tensor-parallel decode
               (benchmarks/shard_bench.py, run as a subprocess because the
               forced host-device count must be set before jax initializes):
               per-device table bytes and decode-GEMV latency at
               model=1/2/4/8 over 8 forced host devices.  Results are
               written to BENCH_pr3.json.
  dwconv.*   — the fused depthwise-conv1d pipeline (quantize + causal
               tap-stack + pack + fetch in VMEM,
               repro.kernels.pcilt_fused_dwconv1d) vs the host-packed
               offsets path, at the Mamba conv-frontend shape (k=4) for
               full-sequence and decode-window regimes.
  shard_conv.* — sharded conv2d with in-VMEM im2col per shard (the
               seg_offset kernels) vs the PR 3 host-im2col + sharded-GEMV
               route at model=4 (subprocess, forced host devices).
               dwconv.* and shard_conv.* write BENCH_pr4.json.
  decode_e2e.* — the end-to-end Mamba decode step at batch 1 (the paper's
               fetch-instead-of-compute claim over the *whole* hot loop):
               dense decode_step vs conv-only PCILT vs full-PCILT (every
               projection a layer-stacked fused table fetch,
               core.serving.convert_mamba_decode) vs the host-packed
               projection baseline; us/step, median-of-reps, CPU
               interpret.  Results are written to BENCH_pr5.json.
  drift.*    — the calibration-drift sentinel: monitored (in-kernel
               saturation counters, ``with_stats=True``) vs unmonitored
               decode step, plus the end-to-end chaos-drift loop (inject →
               detect → demote → recalibrate → repromote).  Results are
               written to BENCH_pr10.json with a ``drift`` block the schema
               cross-checks (the overhead ratio must be the quotient of the
               two timings).
  roofline.* — summary terms per hillclimbed cell (full table:
               ``python -m benchmarks.roofline``).

A sub-benchmark that raises no longer silently vanishes: the failure is
recorded as a ``skipped`` row — both in the CSV (``skipped: <reason>`` in
the derived column) and in the JSON payload (``"skipped"`` key on the row
and a top-level ``skipped`` map) — so a BENCH json can never silently
under-report coverage.

``--smoke`` runs every section with minimal reps and writes the JSON
payloads to a temp directory (the checked-in BENCH files are not
clobbered): the CI guard that keeps this harness executable.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

import numpy as np

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: set by ``main(--smoke)``: minimal reps, JSON to a tempdir.
_SMOKE = False


def _timeit(fn, reps=5, warmup=2):
    """Median-of-reps microseconds per call (the median shrugs off the
    scheduler hiccups that dominate shared/throttled CPU runners, where a
    mean-of-reps ratio between two paths can swing 2x run to run)."""
    if _SMOKE:
        reps, warmup = 1, 1
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times)) * 1e6  # us


def _bench_path(bench_json: str) -> str:
    return bench_json if os.path.isabs(bench_json) else os.path.join(
        REPO_ROOT, bench_json)


_SKIP_PREFIX = "skipped: "


def _guard(rows, skipped, name, fn):
    """Run one sub-benchmark; a failure records a skip row instead of
    silently dropping the whole section (or killing the harness)."""
    try:
        fn()
    except Exception as e:  # noqa: BLE001 — any failure becomes a skip row
        reason = f"{type(e).__name__}: {e}".splitlines()[0][:160]
        skipped[name] = reason
        rows.append((name, 0.0, _SKIP_PREFIX + reason))


def _json_rows(rows):
    out = []
    for name, us, derived in rows:
        d = {"name": name, "us_per_call": round(float(us), 2),
             "derived": derived}
        if isinstance(derived, str) and derived.startswith(_SKIP_PREFIX):
            d["skipped"] = derived[len(_SKIP_PREFIX):]
        out.append(d)
    return out


def paper_rows():
    from benchmarks.paper_claims import all_claims

    out = []
    for name, ours, paper, _ in all_claims():
        out.append((f"paper.{name}", ours, paper if paper is not None else ""))
    return out


def micro_rows():
    import jax
    import jax.numpy as jnp
    from repro.core import (QuantSpec, calibrate, build_grouped_tables,
                            pcilt_linear, quantize, dequantize)

    rows = []
    rng = np.random.default_rng(0)
    for (bits, group, n, out, batch) in [(1, 8, 2048, 256, 256),
                                         (2, 4, 2048, 256, 256),
                                         (4, 2, 1024, 256, 256)]:
        spec = QuantSpec(bits)
        x = jnp.asarray(np.abs(rng.normal(size=(batch, n))), jnp.float32)
        w = jnp.asarray(rng.normal(size=(n, out)), jnp.float32)
        s = calibrate(x, spec)
        T = build_grouped_tables(w, spec, s, group)
        xq = dequantize(quantize(x, spec, s), spec, s)

        dm = jax.jit(lambda xq, w: xq @ w)
        ga = jax.jit(lambda x, T: pcilt_linear(x, T, spec, s, group, path="gather"))
        oh = jax.jit(lambda x, T: pcilt_linear(x, T, spec, s, group, path="onehot"))
        dm(xq, w).block_until_ready()
        t_dm = _timeit(lambda: dm(xq, w).block_until_ready())
        t_ga = _timeit(lambda: ga(x, T).block_until_ready())
        t_oh = _timeit(lambda: oh(x, T).block_until_ready())
        tag = f"b{bits}g{group}_{n}x{out}"
        rows.append((f"micro.dm_{tag}", t_dm, ""))
        rows.append((f"micro.lut_gather_{tag}", t_ga, f"{t_dm/t_ga:.2f}x vs dm"))
        rows.append((f"micro.lut_onehot_{tag}", t_oh, f"{t_dm/t_oh:.2f}x vs dm"))
    return rows


def lm_rows():
    from repro.configs import ARCHS, get_config
    from repro.core.serving import mlp_table_bytes

    rows = []
    for arch in ARCHS:
        cfg = get_config(arch)
        if not cfg.d_ff:
            continue
        b = mlp_table_bytes(cfg.d_model, cfg.d_ff, act_bits=4, group=2)
        rows.append((f"lm.mlp_tables_{arch}", b / 2**20,
                     "MiB/layer @INT4 g=2 — why ext.3 sharing matters"))
    return rows


def fused_rows(bench_json: str = "BENCH_pr1.json"):
    import jax
    import jax.numpy as jnp
    from repro.core import QuantSpec, calibrate, build_grouped_tables, pcilt_linear
    from repro.core.lut_layers import pcilt_conv2d
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    rows = []
    speedups = {}
    skipped = {}
    bits, group = 2, 2
    spec = QuantSpec(bits)

    def gemv_block():
        # --- LM decode-GEMV regime: batch-starved projection [n -> O] -----
        B, n, O = 8, 1024, 1024
        x = jnp.asarray(np.abs(rng.normal(size=(B, n))), jnp.float32)
        w = jnp.asarray(rng.normal(size=(n, O)), jnp.float32)
        s = calibrate(x, spec)
        T = build_grouped_tables(w, spec, s, group)
        # tune-once-and-record through the persistent lookup table; the
        # jitted dispatch below then hits the cache at trace time.
        ops.pcilt_fused_gemv(x, T, spec, s, group, autotune=True)
        host = jax.jit(lambda x: pcilt_linear(x, T, spec, s, group, path="kernel"))
        fused = jax.jit(lambda x: pcilt_linear(x, T, spec, s, group, path="fused"))
        host(x).block_until_ready()
        fused(x).block_until_ready()
        t_host = _timeit(lambda: host(x).block_until_ready())
        t_fused = _timeit(lambda: fused(x).block_until_ready())
        speedups["decode_gemv"] = t_host / t_fused
        tag = f"decode_b{bits}g{group}_{n}x{O}"
        rows.append((f"fused.{tag}_hostpacked", t_host, ""))
        rows.append((f"fused.{tag}_fused", t_fused,
                     f"{t_host / t_fused:.2f}x vs host-packed kernel"))

        Tb = T.astype(jnp.bfloat16)
        ops.pcilt_fused_gemv(x, Tb, spec, s, group, autotune=True)
        fused_b = jax.jit(lambda x: pcilt_linear(x, Tb, spec, s, group, path="fused"))
        fused_b(x).block_until_ready()
        t_fused_b = _timeit(lambda: fused_b(x).block_until_ready())
        speedups["decode_gemv_bf16"] = t_host / t_fused_b
        rows.append((f"fused.{tag}_fused_bf16tab", t_fused_b,
                     f"{t_host / t_fused_b:.2f}x vs host-packed kernel"))

    def conv_block():
        # --- the paper's conv regime: 5x5 filter, small image, low bits ---
        B, H, W, C, kh, kw, Co = 2, 14, 14, 8, 5, 5, 16
        xc = jnp.asarray(np.abs(rng.normal(size=(B, H, W, C))), jnp.float32)
        f = jnp.asarray(rng.normal(size=(kh, kw, C, Co)), jnp.float32)
        sc = calibrate(xc, spec)
        nf = kh * kw * C
        Tc = build_grouped_tables(f.reshape(nf, Co), spec, sc, group)
        ops.pcilt_fused_conv2d(xc, Tc, spec, sc, group, kh, kw, autotune=True)
        hostc = jax.jit(lambda x: pcilt_conv2d(x, f, spec, sc, group, path="kernel"))
        fusedc = jax.jit(lambda x: pcilt_conv2d(x, f, spec, sc, group, path="fused"))
        hostc(xc).block_until_ready()
        fusedc(xc).block_until_ready()
        t_hostc = _timeit(lambda: hostc(xc).block_until_ready())
        t_fusedc = _timeit(lambda: fusedc(xc).block_until_ready())
        speedups["conv5x5"] = t_hostc / t_fusedc
        tagc = f"conv5x5_b{bits}g{group}_{C}to{Co}"
        rows.append((f"fused.{tagc}_hostpacked", t_hostc, ""))
        rows.append((f"fused.{tagc}_fused", t_fusedc,
                     f"{t_hostc / t_fusedc:.2f}x vs host-packed kernel"))

    _guard(rows, skipped, "fused.decode_gemv", gemv_block)
    _guard(rows, skipped, "fused.conv5x5", conv_block)

    if bench_json:
        payload = {
            "pr": 1,
            "backend": jax.default_backend(),
            "timing": "interpret-mode CPU" if jax.default_backend() != "tpu"
                      else "compiled TPU",
            "target_min_speedup": {k: 1.3 for k in speedups},
            "speedup": {k: round(v, 3) for k, v in speedups.items()},
            "skipped": skipped,
            "rows": _json_rows(rows),
        }
        with open(_bench_path(bench_json), "w") as fp:
            json.dump(payload, fp, indent=1)
    return rows


def shared_rows(bench_json: str = "BENCH_pr2.json"):
    import jax
    import jax.numpy as jnp
    from repro.core import (QuantSpec, calibrate, build_shared_grouped_tables,
                            pcilt_linear)
    from repro.core.lut_layers import pcilt_conv2d
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    rows = []
    speedups = {}
    ratios = {}
    skipped = {}
    bits, group = 2, 2
    spec = QuantSpec(bits)

    def codebook_weights(n, O, group, X):
        # Weight-clustered / palettized regime (the ext.-3 precondition):
        # [group, O] segments drawn from an X-entry codebook.
        G = n // group
        cb = rng.normal(size=(X, group, O))
        return jnp.asarray(cb[rng.integers(0, X, G)].reshape(n, O),
                           jnp.float32)

    def gemv_block():
        # --- LM decode-GEMV regime over a weight-clustered projection -----
        B, n, O, X = 8, 1024, 1024, 16
        x = jnp.asarray(np.abs(rng.normal(size=(B, n))), jnp.float32)
        w = codebook_weights(n, O, group, X)
        s = calibrate(x, spec)
        st = build_shared_grouped_tables(w, spec, s, group)
        T = st.materialize()  # dense [G, V, O] (for the dense-fused comparison)
        ops.pcilt_shared_gemv(x, st.pool, st.seg_idx, spec, s, group,
                              autotune=True)
        ops.pcilt_fused_gemv(x, T, spec, s, group, autotune=True)
        ga = jax.jit(lambda x: pcilt_linear(x, st, spec, s, group, path="gather"))
        sh = jax.jit(lambda x: pcilt_linear(x, st, spec, s, group, path="shared"))
        fu = jax.jit(lambda x: pcilt_linear(x, T, spec, s, group, path="fused"))
        for f in (ga, sh, fu):
            f(x).block_until_ready()
        t_ga = _timeit(lambda: ga(x).block_until_ready())
        t_sh = _timeit(lambda: sh(x).block_until_ready())
        t_fu = _timeit(lambda: fu(x).block_until_ready())
        speedups["decode_gemv_vs_gather"] = t_ga / t_sh
        speedups["decode_gemv_vs_dense_fused"] = t_fu / t_sh
        ratios["decode_gemv_table_mem"] = st.dedup_ratio
        tag = f"decode_b{bits}g{group}_{n}x{O}_X{st.pool_cardinality}"
        rows.append((f"shared.{tag}_gather", t_ga, ""))
        rows.append((f"shared.{tag}_dense_fused", t_fu, ""))
        rows.append((f"shared.{tag}_fused_shared", t_sh,
                     f"{t_ga / t_sh:.2f}x vs gather, {t_fu / t_sh:.2f}x vs "
                     f"dense-fused"))
        rows.append((f"shared.{tag}_table_mem_ratio", st.dedup_ratio,
                     f"dense {st.dense_bytes()/2**20:.1f} MiB -> pool "
                     f"{st.pool_bytes()/2**20:.2f} MiB"))

    def conv_block():
        # --- the paper's conv regime: 5x5 filter, weight-clustered.  Co=64
        # (a realistic channel width) is where the pooled X*V-lane
        # contraction pulls clear of both the gather and the dense
        # Gb*V-lane fused contraction. ---
        B, H, W, C, kh, kw, Co, Xc = 2, 14, 14, 8, 5, 5, 64, 8
        xc = jnp.asarray(np.abs(rng.normal(size=(B, H, W, C))), jnp.float32)
        nf = kh * kw * C
        wc = codebook_weights(nf, Co, group, Xc)
        f = jnp.asarray(np.asarray(wc).reshape(kh, kw, C, Co), jnp.float32)
        sc = calibrate(xc, spec)
        stc = build_shared_grouped_tables(wc, spec, sc, group)
        Tc = stc.materialize()
        ops.pcilt_shared_conv2d(xc, stc.pool, stc.seg_idx, spec, sc, group,
                                kh, kw, autotune=True)
        ops.pcilt_fused_conv2d(xc, Tc, spec, sc, group, kh, kw, autotune=True)
        gac = jax.jit(lambda x: pcilt_conv2d(x, f, spec, sc, group, tables=stc,
                                             path="gather"))
        shc = jax.jit(lambda x: pcilt_conv2d(x, f, spec, sc, group, tables=stc,
                                             path="shared"))
        fuc = jax.jit(lambda x: pcilt_conv2d(x, f, spec, sc, group, tables=Tc,
                                             path="fused"))
        for fn in (gac, shc, fuc):
            fn(xc).block_until_ready()
        t_gac = _timeit(lambda: gac(xc).block_until_ready())
        t_shc = _timeit(lambda: shc(xc).block_until_ready())
        t_fuc = _timeit(lambda: fuc(xc).block_until_ready())
        speedups["conv5x5_vs_gather"] = t_gac / t_shc
        speedups["conv5x5_vs_dense_fused"] = t_fuc / t_shc
        ratios["conv5x5_table_mem"] = stc.dedup_ratio
        tagc = f"conv5x5_b{bits}g{group}_{C}to{Co}_X{stc.pool_cardinality}"
        rows.append((f"shared.{tagc}_gather", t_gac, ""))
        rows.append((f"shared.{tagc}_dense_fused", t_fuc, ""))
        rows.append((f"shared.{tagc}_fused_shared", t_shc,
                     f"{t_gac / t_shc:.2f}x vs gather, {t_fuc / t_shc:.2f}x "
                     f"vs dense-fused"))
        rows.append((f"shared.{tagc}_table_mem_ratio", stc.dedup_ratio,
                     f"dense {stc.dense_bytes()/2**10:.0f} KiB -> pool "
                     f"{stc.pool_bytes()/2**10:.0f} KiB"))

    _guard(rows, skipped, "shared.decode_gemv", gemv_block)
    _guard(rows, skipped, "shared.conv5x5", conv_block)

    if bench_json:
        payload = {
            "pr": 2,
            "backend": jax.default_backend(),
            "timing": "interpret-mode CPU" if jax.default_backend() != "tpu"
                      else "compiled TPU",
            "target_min_speedup": {k: 1.0 for k in speedups},
            "speedup": {k: round(v, 3) for k, v in speedups.items()},
            "table_mem_ratio": {k: round(v, 3) for k, v in ratios.items()},
            "skipped": skipped,
            "rows": _json_rows(rows),
        }
        with open(_bench_path(bench_json), "w") as fp:
            json.dump(payload, fp, indent=1)
    return rows


def _shard_subprocess(argv, timeout=1800):
    """Run benchmarks/shard_bench.py in a subprocess (it must force the host
    device count before jax initializes — this process has usually already
    initialized jax on 1 device).  Raises RuntimeError with a one-line
    detail on timeout or a non-zero exit."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    try:
        r = subprocess.run(
            [sys.executable, "-m", "benchmarks.shard_bench"] + argv,
            cwd=REPO_ROOT, env=env, capture_output=True, text=True,
            timeout=timeout,
        )
    except subprocess.TimeoutExpired:
        raise RuntimeError(f"shard_bench timed out after {timeout}s") from None
    if r.returncode != 0:
        lines = (r.stderr or r.stdout).strip().splitlines()
        raise RuntimeError(lines[-1][:160] if lines
                           else f"exit code {r.returncode}")


def shard_rows(bench_json: str = "BENCH_pr3.json"):
    """Relay the rows the shard_bench subprocess recorded (shard.* section)."""
    out = _bench_path(bench_json)
    try:
        _shard_subprocess(["--out", out] + (["--smoke"] if _SMOKE else []))
    except RuntimeError as e:
        return [("shard.error", 0.0, _SKIP_PREFIX + str(e))]
    payload = json.load(open(out))
    return [(row["name"], row["us_per_call"], row["derived"])
            for row in payload["rows"]]


def pr4_rows(bench_json: str = "BENCH_pr4.json"):
    """dwconv.* + shard_conv.* -> BENCH_pr4.json.

    * **dwconv.*** — the fused depthwise-conv1d pipeline vs the host-packed
      offsets path at the Mamba conv-frontend shape (k=4 taps, 2-bit codes):
      the full-sequence causal regime and the ``[B, k, C]`` decode-window
      regime (one fetch per channel).
    * **shard_conv.*** — sharded conv2d with in-VMEM im2col per shard (the
      ``seg_offset`` kernels) vs the PR 3 host-im2col + sharded-GEMV route
      at model=4, measured in the forced-host-device subprocess.
    """
    import jax

    rows = []
    speedups = {}
    skipped = {}

    def dwconv_block():
        import jax.numpy as jnp
        from repro.core import QuantSpec, calibrate
        from repro.core.lut_layers import (build_dwconv_tables,
                                           pcilt_depthwise_conv1d)
        from repro.kernels import ops

        # Batch-starved decode-chunk regime (the PCILT serving target): on a
        # throttled CPU runner the host kernel's 256-step V-loop overhead is
        # the signal here, and it dominates most reliably at small row tiles.
        rng = np.random.default_rng(0)
        bits, k = 2, 4
        B, T, C = 1, 128, 96
        if _SMOKE:
            T, C = 64, 64
        spec = QuantSpec(bits)
        x = jnp.asarray(np.abs(rng.normal(size=(B, T, C))), jnp.float32)
        f = jnp.asarray(rng.normal(size=(k, C)), jnp.float32)
        s = calibrate(x, spec)
        tab = build_dwconv_tables(f, spec, s)
        ops.pcilt_fused_dwconv1d(x, tab, spec, s, k, autotune=True)
        host = jax.jit(lambda a: pcilt_depthwise_conv1d(
            a, f, spec, s, tables=tab, path="kernel"))
        fused = jax.jit(lambda a: pcilt_depthwise_conv1d(
            a, f, spec, s, tables=tab, path="fused"))
        host(x).block_until_ready()
        fused(x).block_until_ready()
        t_host = _timeit(lambda: host(x).block_until_ready())
        t_fused = _timeit(lambda: fused(x).block_until_ready())
        speedups["dwconv_fused_vs_hostpacked"] = t_host / t_fused
        tag = f"causal_b{bits}k{k}_T{T}xC{C}"
        rows.append((f"dwconv.{tag}_hostpacked", t_host,
                     "host quantize+tap-stack+pack, V-loop kernel"))
        rows.append((f"dwconv.{tag}_fused", t_fused,
                     f"{t_host / t_fused:.2f}x vs host-packed offsets"))

        # decode-window regime: the assembled [B, k, C] window, one output
        xw = x[:, :k]
        ops.pcilt_fused_dwconv1d(xw, tab, spec, s, k, padding="VALID",
                                 autotune=True)
        hostw = jax.jit(lambda a: pcilt_depthwise_conv1d(
            a, f, spec, s, tables=tab, path="kernel", padding="VALID"))
        fusedw = jax.jit(lambda a: pcilt_depthwise_conv1d(
            a, f, spec, s, tables=tab, path="fused", padding="VALID"))
        hostw(xw).block_until_ready()
        fusedw(xw).block_until_ready()
        t_hw = _timeit(lambda: hostw(xw).block_until_ready())
        t_fw = _timeit(lambda: fusedw(xw).block_until_ready())
        speedups["dwconv_decode_window_fused_vs_hostpacked"] = t_hw / t_fw
        rows.append((f"dwconv.decode_window_b{bits}k{k}_C{C}_hostpacked",
                     t_hw, ""))
        rows.append((f"dwconv.decode_window_b{bits}k{k}_C{C}_fused", t_fw,
                     f"{t_hw / t_fw:.2f}x vs host-packed offsets"))

    def shard_conv_block():
        tmp = tempfile.NamedTemporaryFile(suffix=".json", delete=False)
        tmp.close()
        try:
            _shard_subprocess(["--conv-json", tmp.name, "--model", "4"]
                              + (["--smoke"] if _SMOKE else []))
            payload = json.load(open(tmp.name))
            speedups.update(payload["speedup"])
            rows.extend((row["name"], row["us_per_call"], row["derived"])
                        for row in payload["rows"])
        finally:
            os.unlink(tmp.name)

    _guard(rows, skipped, "dwconv.causal", dwconv_block)
    _guard(rows, skipped, "shard_conv.model4", shard_conv_block)

    if bench_json:
        payload = {
            "pr": 4,
            "backend": jax.default_backend(),
            "timing": "interpret-mode CPU" if jax.default_backend() != "tpu"
                      else "compiled TPU",
            "target_min_speedup": {"dwconv_fused_vs_hostpacked": 2.0,
                                   "shard_conv_in_vmem_vs_host_im2col_m4": 1.2},
            "speedup": {k: round(v, 3) for k, v in speedups.items()},
            "skipped": skipped,
            "rows": _json_rows(rows),
        }
        with open(_bench_path(bench_json), "w") as fp:
            json.dump(payload, fp, indent=1)
    return rows


def decode_e2e_rows(bench_json: str = "BENCH_pr5.json"):
    """decode_e2e.* -> BENCH_pr5.json: the batch-1 Mamba decode step.

    Four variants of the same ``MambaLM.decode_step``:

    * **dense** — every projection a matmul, conv a tap-dot;
    * **conv_only_pcilt** — PR 4 state: conv frontend fetches, projections
      still dense;
    * **full_pcilt_hostpacked_proj** — every projection a PCILT fetch via
      the host-packed pipeline (quantize + pack offsets in HBM, per-layer
      table slice copied out of the stack each scan step) — the baseline
      the stacked kernel exists to beat;
    * **full_pcilt_fused** — the PR 5 path: layer-stacked ``[L, G, V, O]``
      tables resident, scalar-prefetch staging, quantize→pack→fetch in VMEM
      (``convert_mamba_decode``).

    All variants share one calibration and one jit each; us/step is the
    median over reps of the full step (embed → L scanned blocks → logits).
    """
    import dataclasses

    import jax
    import jax.numpy as jnp

    rows = []
    speedups = {}
    skipped = {}

    def block():
        from repro.configs import get_smoke_config
        from repro.configs.base import PCILTConfig
        from repro.core.serving import convert_mamba_decode
        from repro.models import build_model
        from repro.nn import materialize
        from repro.nn.layers import Ctx

        cfg = get_smoke_config("mamba2-130m")
        if not _SMOKE:
            # Batch-starved decode at a width where the projections dominate
            # per-step FLOPs (the regime the stacked path targets); smoke
            # keeps the CI-sized smoke dims.
            cfg = dataclasses.replace(
                cfg, d_model=256,
                ssm=dataclasses.replace(cfg.ssm, d_state=64, head_dim=64))
        cfg = dataclasses.replace(cfg, pcilt=PCILTConfig(act_bits=2, group=2),
                                  dtype=jnp.float32)
        model = build_model(cfg)
        key = jax.random.PRNGKey(0)
        params = materialize(model.param_specs(), key)
        ctx = Ctx()
        B, S = 1, 16
        calib = jax.random.randint(key, (B, S), 0, cfg.vocab)
        _, cache = model.prefill(params, {"tokens": calib}, ctx)
        tok = jax.random.randint(key, (B, 1), 0, cfg.vocab)

        eng = convert_mamba_decode(model, params, calib)
        eng.tune(batch=B)  # record fused_gemv_stacked winners before jitting
        # Tune the host-packed baseline's kernels too (same eager
        # tune-once-and-record, per projection shape) so the comparison is
        # stacked-vs-host-packed architecture, not tuned-vs-heuristic tiles.
        from repro.kernels import ops

        for t in eng.pcilt["proj"]["tables"].values():
            off = jnp.zeros((B, t.shape[1]), jnp.int32)
            ops.pcilt_gemv(off, t[0], autotune=True)
        conv_only = {k: v for k, v in eng.pcilt.items() if k != "proj"}
        hostpacked = dict(eng.pcilt,
                          proj=dict(eng.pcilt["proj"], path="kernel"))
        variants = [
            ("dense", None),
            ("conv_only_pcilt", conv_only),
            ("full_pcilt_hostpacked_proj", hostpacked),
            ("full_pcilt_fused", eng.pcilt),
        ]
        times = {}
        for name, pc in variants:
            fn = jax.jit(lambda p, c, t, pc=pc: model.decode_step(
                p, c, t, ctx, pcilt=pc))
            fn(params, cache, tok)[0].block_until_ready()
            times[name] = _timeit(
                lambda: fn(params, cache, tok)[0].block_until_ready())
        speedups["full_pcilt_vs_hostpacked_proj"] = (
            times["full_pcilt_hostpacked_proj"] / times["full_pcilt_fused"])
        speedups["full_pcilt_vs_dense"] = (
            times["dense"] / times["full_pcilt_fused"])
        speedups["conv_only_vs_dense"] = (
            times["dense"] / times["conv_only_pcilt"])
        tag = (f"b1_d{cfg.d_model}_L{cfg.n_layers}"
               f"_bits{cfg.pcilt.act_bits}g{cfg.pcilt.group}")
        rows.append((f"decode_e2e.{tag}_dense", times["dense"],
                     f"{1e6 / times['dense']:.1f} tokens/s"))
        rows.append((f"decode_e2e.{tag}_conv_only_pcilt",
                     times["conv_only_pcilt"],
                     f"{speedups['conv_only_vs_dense']:.2f}x vs dense"))
        rows.append((f"decode_e2e.{tag}_full_pcilt_hostpacked_proj",
                     times["full_pcilt_hostpacked_proj"],
                     "host quantize+pack, per-step table-slice copy"))
        rows.append((f"decode_e2e.{tag}_full_pcilt_fused",
                     times["full_pcilt_fused"],
                     f"{speedups['full_pcilt_vs_hostpacked_proj']:.2f}x vs "
                     f"host-packed proj, "
                     f"{speedups['full_pcilt_vs_dense']:.2f}x vs dense"))
        rows.append((f"decode_e2e.{tag}_table_mib",
                     eng.table_bytes() / 2**20,
                     "conv [L,C,V] + stacked proj [L,G,V,O] tables"))

    _guard(rows, skipped, "decode_e2e.batch1", block)

    if bench_json:
        payload = {
            "pr": 5,
            "backend": jax.default_backend(),
            "timing": "interpret-mode CPU" if jax.default_backend() != "tpu"
                      else "compiled TPU",
            "target_min_speedup": {"full_pcilt_vs_hostpacked_proj": 1.5},
            "speedup": {k: round(v, 3) for k, v in speedups.items()},
            "skipped": skipped,
            "rows": _json_rows(rows),
        }
        with open(_bench_path(bench_json), "w") as fp:
            json.dump(payload, fp, indent=1)
    return rows


def decode_e2e_pr8_rows(bench_json: str = "BENCH_pr8.json"):
    """decode_e2e_pr8.* -> BENCH_pr8.json: paired multi-scalar decode.

    The PR 8 claim: TL1-style paired tables (adjacent segment pairs merged
    into seg-major ``[G/2, L, V^2, O]`` stacks, fetched by ``take_along_
    axis`` row-gather instead of a one-hot contraction) halve the fetch
    count per output and make the fully-converted PCILT decode step **beat
    dense** on the PR 5 config — the end-to-end target the unpaired fused
    path missed.  Measured on the identical model/calibration as
    ``decode_e2e_rows``:

    * **dense** — every projection a matmul, conv a tap-dot;
    * **full_pcilt_fused** — the PR 5 unpaired stacked path (baseline);
    * **full_pcilt_paired** — the paired stacked path (this PR), with the
      conv frontend's dwconv key tuned at warmup like the projections;
    * **paired_parity** — paired-vs-unpaired fetch parity on an
      exact-arithmetic grid (integer weights, power-of-two scales: every
      summation order is exact, so the two table layouts must agree
      *bit-for-bit*; any nonzero diff is a build/kernel index bug).
    """
    import dataclasses

    import jax
    import jax.numpy as jnp

    rows = []
    speedups = {}
    skipped = {}

    def block():
        from repro.configs import get_smoke_config
        from repro.configs.base import PCILTConfig
        from repro.core import QuantSpec
        from repro.core.pcilt import build_grouped_tables, build_paired_tables
        from repro.core.serving import convert_mamba_decode
        from repro.kernels import ops
        from repro.models import build_model
        from repro.nn import materialize
        from repro.nn.layers import Ctx

        cfg = get_smoke_config("mamba2-130m")
        if not _SMOKE:
            # The PR 5 decode_e2e config — the regime the paired path must
            # win in; smoke keeps the CI-sized smoke dims.
            cfg = dataclasses.replace(
                cfg, d_model=256,
                ssm=dataclasses.replace(cfg.ssm, d_state=64, head_dim=64))
        cfg = dataclasses.replace(cfg, pcilt=PCILTConfig(act_bits=2, group=2),
                                  dtype=jnp.float32)
        model = build_model(cfg)
        key = jax.random.PRNGKey(0)
        params = materialize(model.param_specs(), key)
        ctx = Ctx()
        B, S = 1, 16
        calib = jax.random.randint(key, (B, S), 0, cfg.vocab)
        _, cache = model.prefill(params, {"tokens": calib}, ctx)
        tok = jax.random.randint(key, (B, 1), 0, cfg.vocab)

        eng_u = convert_mamba_decode(model, params, calib)
        eng_p = convert_mamba_decode(model, params, calib, paired=True)
        eng_u.tune(batch=B)  # records stacked + dwconv winners
        eng_p.tune(batch=B)  # records paired-stacked + dwconv winners

        variants = [
            ("dense", None),
            ("full_pcilt_fused", eng_u.pcilt),
            ("full_pcilt_paired", eng_p.pcilt),
        ]
        times = {}
        for name, pc in variants:
            fn = jax.jit(lambda p, c, t, pc=pc: model.decode_step(
                p, c, t, ctx, pcilt=pc))
            fn(params, cache, tok)[0].block_until_ready()
            times[name] = _timeit(
                lambda: fn(params, cache, tok)[0].block_until_ready())
        speedups["full_pcilt_vs_dense"] = (
            times["dense"] / times["full_pcilt_paired"])
        speedups["paired_vs_unpaired"] = (
            times["full_pcilt_fused"] / times["full_pcilt_paired"])

        # paired-vs-unpaired bit-exactness probe on the exact grid: integer
        # weights + power-of-two scale make every summation order exact, so
        # the [G, V, O] and [G/2, V^2, O] layouts must agree bit-for-bit.
        spec = QuantSpec(bits=2, symmetric=True)
        kw = jax.random.randint(
            jax.random.PRNGKey(7), (64, 128), -2, 3).astype(jnp.float32)
        scale = jnp.float32(0.5)  # power of two: quantize is exact
        xs = jax.random.randint(
            jax.random.PRNGKey(8), (4, 64), -2, 2).astype(jnp.float32)
        t_u = build_grouped_tables(kw, spec, scale, 2)
        t_p = build_paired_tables(kw, spec, scale, 2)
        out_u = ops.pcilt_fused_gemv(xs, t_u, spec, scale, 2)
        out_p = ops.pcilt_fused_gemv_paired(xs, t_p, spec, scale, 2)
        diff = float(jnp.max(jnp.abs(out_u - out_p)))
        if diff != 0.0:
            raise AssertionError(
                f"paired tables are not bit-exact vs unpaired on the exact-"
                f"arithmetic grid (max diff {diff})")

        tag = (f"b1_d{cfg.d_model}_L{cfg.n_layers}"
               f"_bits{cfg.pcilt.act_bits}g{cfg.pcilt.group}")
        rows.append((f"decode_e2e_pr8.{tag}_dense", times["dense"],
                     f"{1e6 / times['dense']:.1f} tokens/s"))
        rows.append((f"decode_e2e_pr8.{tag}_full_pcilt_fused",
                     times["full_pcilt_fused"],
                     "unpaired stacked path (PR 5 baseline)"))
        rows.append((f"decode_e2e_pr8.{tag}_full_pcilt_paired",
                     times["full_pcilt_paired"],
                     f"{speedups['paired_vs_unpaired']:.2f}x vs unpaired, "
                     f"{speedups['full_pcilt_vs_dense']:.2f}x vs dense"))
        rows.append((f"decode_e2e_pr8.{tag}_paired_parity", diff,
                     "max |paired - unpaired| on the exact grid "
                     "(bit-exact contract: must be 0)"))
        rows.append((f"decode_e2e_pr8.{tag}_paired_table_mib",
                     eng_p.table_bytes() / 2**20,
                     "conv [L,C,V] + seg-major paired proj [G/2,L,V^2,O]"))

    _guard(rows, skipped, "decode_e2e_pr8.batch1", block)

    if bench_json:
        payload = {
            "pr": 8,
            "backend": jax.default_backend(),
            "timing": "interpret-mode CPU" if jax.default_backend() != "tpu"
                      else "compiled TPU",
            "target_min_speedup": {"full_pcilt_vs_dense": 1.0},
            "speedup": {k: round(v, 3) for k, v in speedups.items()},
            "skipped": skipped,
            "rows": _json_rows(rows),
        }
        with open(_bench_path(bench_json), "w") as fp:
            json.dump(payload, fp, indent=1)
    return rows


def resilience_rows(bench_json: str = "BENCH_pr6.json"):
    """resilience.* -> BENCH_pr6.json: what the serving health layer costs.

    The monitor's design claim is that health checking is *amortized*: one
    layer's CRC per tick (plus a dense-oracle probe every few clean checks),
    so the steady-state overhead stays flat in depth.  Measured here:

    * **step_us** — the converted decode step alone (all-healthy masks);
    * **step_monitored_us** — the same step plus ``HealthMonitor.on_tick``
      (the per-tick serving cost), and the implied overhead %;
    * **verify_full_us** — a full-bundle ``verify_integrity`` sweep (every
      layer of every stacked table + the head), the *worst-case* on-demand
      check a load or an incident response pays.
    """
    import dataclasses

    import jax
    import jax.numpy as jnp

    rows = []
    skipped = {}

    def block():
        from repro.configs import get_smoke_config
        from repro.configs.base import PCILTConfig
        from repro.core.serving import HealthMonitor, convert_mamba_decode
        from repro.models import build_model
        from repro.nn import materialize
        from repro.nn.layers import Ctx

        cfg = get_smoke_config("mamba2-130m")
        cfg = dataclasses.replace(cfg, pcilt=PCILTConfig(act_bits=2, group=2),
                                  dtype=jnp.float32)
        model = build_model(cfg)
        key = jax.random.PRNGKey(0)
        params = materialize(model.param_specs(), key)
        calib = jax.random.randint(key, (1, 16), 0, cfg.vocab)
        _, cache = model.prefill(params, {"tokens": calib}, Ctx())
        tok = jax.random.randint(key, (1, 1), 0, cfg.vocab)

        eng = convert_mamba_decode(model, params, calib, head="shared")
        mon = HealthMonitor(eng, params)
        lmask, hmask = mon.ok_masks()
        eng.step(params, cache, tok, lmask, hmask)[0].block_until_ready()

        step_us = _timeit(lambda: eng.step(
            params, cache, tok, lmask, hmask)[0].block_until_ready())
        tick = [0]

        def monitored():
            eng.step(params, cache, tok, *mon.ok_masks())[0]\
                .block_until_ready()
            mon.on_tick(tick[0])
            tick[0] += 1

        monitored_us = _timeit(monitored)
        verify_us = _timeit(lambda: eng.verify_integrity())
        over = 100.0 * (monitored_us - step_us) / step_us
        tag = f"L{cfg.n_layers}_d{cfg.d_model}"
        rows.append((f"resilience.{tag}_step_us", step_us,
                     "converted decode step, all-healthy masks"))
        rows.append((f"resilience.{tag}_step_monitored_us", monitored_us,
                     f"+HealthMonitor.on_tick: {over:.1f}% overhead"))
        rows.append((f"resilience.{tag}_verify_full_us", verify_us,
                     "CRC every layer of every table + head (on-demand)"))

    _guard(rows, skipped, "resilience.monitor", block)

    if bench_json:
        payload = {
            "pr": 6,
            "backend": jax.default_backend(),
            "timing": "interpret-mode CPU" if jax.default_backend() != "tpu"
                      else "compiled TPU",
            "skipped": skipped,
            "rows": _json_rows(rows),
        }
        with open(_bench_path(bench_json), "w") as fp:
            json.dump(payload, fp, indent=1)
    return rows


def traffic_rows(bench_json: str = "BENCH_pr9.json"):
    """traffic.* -> BENCH_pr9.json: traffic-hardened serving.

    The PR 9 claims, measured on the virtual clock (seeded arrivals, no
    wall-clock flake): the R-aware tuned stacked decode at R=8 beats 8
    sequential batch-1 steps >= 2x bit-exactly; the bounded-admission
    engine at 2x offered load sheds with typed ``rejected`` outcomes while
    admitted p99 per-token latency stays within 2x of the 0.5x-load p99;
    and the PR 6 chaos schedule injected mid-stream keeps every undegraded
    request token-identical to a fault-free run of the same arrival trace.
    ``benchmarks/traffic_bench.py`` holds the blocks; contract violations
    raise inside the guard and land as skip rows (non-zero exit in smoke)."""
    from benchmarks.traffic_bench import collect

    return collect(bench_json and _bench_path(bench_json), _SMOKE, _timeit,
                   _guard, _json_rows)


def drift_rows(bench_json: str = "BENCH_pr10.json"):
    """drift.* -> BENCH_pr10.json: the calibration-drift sentinel.

    Two claims, measured on the PR 5/6 smoke config:

    * **sentinel overhead** — the converted decode step with in-kernel
      saturation counters (``with_stats=True``: per-layer clipped-element
      count + peak ``|x|/scale``, reduced in VMEM) plus the host-side
      ``observe_saturation`` classification, vs the identical step
      uncounted.  The monitored/unmonitored ratio lands in the BENCH
      ``drift.sentinel_overhead`` block; ``analysis/schema.py`` re-derives
      it from the two timings, so a hand-edited ratio cannot claim an
      overhead the timings don't show.  Target: <= 1.10x.
    * **chaos-drift loop** — the serve engine under the ``--chaos-drift``
      schedule: parameter drift injected mid-stream (no corrupted bytes),
      caught by the counters, answered with a typed drift demotion,
      rollback, online recalibration, and repromotion.  The event counts
      land in ``drift.chaos``; missing demotions/recalibrations or a
      layer left demoted raise inside the guard (a skip row, non-zero CI
      exit in smoke).
    """
    import dataclasses

    import jax
    import jax.numpy as jnp

    rows = []
    skipped = {}
    drift_block = {}

    def smoke_cfg():
        from repro.configs import get_smoke_config
        from repro.configs.base import PCILTConfig

        cfg = get_smoke_config("mamba2-130m")
        return dataclasses.replace(cfg,
                                   pcilt=PCILTConfig(act_bits=2, group=2),
                                   dtype=jnp.float32)

    def overhead():
        from repro.core.serving import HealthMonitor, convert_mamba_decode
        from repro.models import build_model
        from repro.nn import materialize
        from repro.nn.layers import Ctx

        cfg = smoke_cfg()
        if not _SMOKE:
            # The decode_e2e width: per-kernel interpret overhead amortizes
            # over real tile work there, so the ratio measures the counters,
            # not the harness.  Smoke keeps the CI-sized dims (the target is
            # asserted on the checked-in full run, not the smoke guard).
            cfg = dataclasses.replace(
                cfg, d_model=256,
                ssm=dataclasses.replace(cfg.ssm, d_state=64, head_dim=64))
        model = build_model(cfg)
        key = jax.random.PRNGKey(0)
        params = materialize(model.param_specs(), key)
        calib = jax.random.randint(key, (1, 16), 0, cfg.vocab)
        _, cache = model.prefill(params, {"tokens": calib}, Ctx())
        tok = jax.random.randint(key, (1, 1), 0, cfg.vocab)

        eng = convert_mamba_decode(model, params, calib)
        eng.tune(batch=1)
        mon = HealthMonitor(eng, params)
        lmask, hmask = mon.ok_masks()  # captured once: fixed all-healthy
        eng.step(params, cache, tok, lmask, hmask)[0].block_until_ready()
        eng.step(params, cache, tok, lmask, hmask,
                 with_stats=True)[0].block_until_ready()

        plain_us = _timeit(lambda: eng.step(
            params, cache, tok, lmask, hmask)[0].block_until_ready())
        tick = [0]

        def monitored():
            logits, _, sat = eng.step(params, cache, tok, lmask, hmask,
                                      with_stats=True)
            logits.block_until_ready()
            mon.observe_saturation(tick[0], sat, rows=1)
            tick[0] += 1

        monitored_us = _timeit(monitored)
        # store the rounded values and derive the ratio from them, so the
        # schema's quotient cross-check sees exactly consistent numbers.
        m, u = round(monitored_us, 2), round(plain_us, 2)
        ratio = round(m / u, 4)
        drift_block["sentinel_overhead"] = {
            "monitored_us": m, "unmonitored_us": u, "ratio": ratio}
        tag = f"L{cfg.n_layers}_d{cfg.d_model}"
        rows.append((f"drift.{tag}_step_us", plain_us,
                     "converted decode step, counters off"))
        rows.append((f"drift.{tag}_step_monitored_us", monitored_us,
                     f"{ratio:.3f}x vs uncounted (in-kernel saturation "
                     f"counters + observe_saturation; target <= 1.10x)"))

    def chaos():
        from repro.launch.serve import (DRIFT_LAYER, Engine, Request,
                                        _chaos_drift_plan)
        from repro.runtime.faults import FaultInjector

        cfg = smoke_cfg()
        eng = Engine(cfg, max_len=64, slots=2, pcilt=True)
        injector = FaultInjector(seed=0)
        eng.chaos = _chaos_drift_plan(eng, injector)
        rng = np.random.default_rng(0)
        reqs = [Request(i, rng.integers(2, cfg.vocab, size=6), max_new=4)
                for i in range(3)]
        t0 = time.perf_counter()
        stats = eng.run(reqs)
        wall_us = (time.perf_counter() - t0) * 1e6
        events = stats["health_events"]
        demotions = [e for e in events if e["kind"] == "drift"]
        recals = [e for e in events if e["kind"] == "recalibrate"]
        sticky = [e for e in events if e["kind"] == "drift_sticky"]
        repromoted = bool(all(eng.monitor.layer_ok))
        if not demotions:
            raise AssertionError("injected drift produced no drift demotion")
        if any(e["layer"] != DRIFT_LAYER for e in demotions):
            raise AssertionError("drift demotion fired on an undrifted layer")
        if not recals:
            raise AssertionError("drift demotion was never recalibrated")
        if not repromoted:
            raise AssertionError("drifted layer was not repromoted")
        drift_block["chaos"] = {
            "demotions": len(demotions), "recalibrations": len(recals),
            "sticky": len(sticky), "repromoted": repromoted}
        rows.append(("drift.chaos_inject_to_repromote_us", wall_us,
                     f"{len(demotions)} drift demotion(s) at layer "
                     f"{DRIFT_LAYER} -> {len(recals)} recalibration(s) -> "
                     f"repromoted; {stats['rollbacks']} rollback(s), "
                     f"no request lost"))

    _guard(rows, skipped, "drift.sentinel_overhead", overhead)
    _guard(rows, skipped, "drift.chaos_loop", chaos)

    if bench_json:
        payload = {
            "pr": 10,
            "backend": jax.default_backend(),
            "timing": "interpret-mode CPU" if jax.default_backend() != "tpu"
                      else "compiled TPU",
            "skipped": skipped,
            "rows": _json_rows(rows),
        }
        if "sentinel_overhead" in drift_block:
            payload["drift"] = drift_block
        with open(_bench_path(bench_json), "w") as fp:
            json.dump(payload, fp, indent=1)
    return rows


def roofline_rows():
    import glob
    import json
    import os
    from benchmarks.roofline import terms, DRYRUN_DIR

    rows = []
    targets = [
        ("llama4-maverick-400b-a17b", "train_4k", "pod16x16"),
        ("qwen3-0.6b", "train_4k", "pod16x16"),
        ("granite-moe-3b-a800m", "decode_32k", "pod16x16"),
    ]
    for arch, shape, mesh in targets:
        safe = arch.replace(".", "_")
        p = os.path.join(DRYRUN_DIR, f"{safe}__{shape}__{mesh}.json")
        if not os.path.exists(p):
            continue
        c = json.load(open(p))
        if c["status"] != "ok":
            continue
        t_c, t_m, t_k, dom, frac, useful = terms(c)
        rows.append((f"roofline.{arch}.{shape}.step_s",
                     (max(t_c, t_m, t_k)) * 1e6,
                     f"dom={dom} frac={frac:.3f} useful={useful:.3f}"))
    return rows


def main(argv=None) -> None:
    import argparse
    import functools

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="minimal reps, JSON to a tempdir (CI harness guard "
                         "— checked-in BENCH files are not touched)")
    ap.add_argument("--only", default=None, metavar="SECTION",
                    help="run a single section by prefix (e.g. decode_e2e) — "
                         "the CI decode-smoke step uses this to guard the "
                         "end-to-end decode benchmark in isolation")
    ap.add_argument("--skip", default=None, metavar="SECTION",
                    help="drop one section by prefix — the CI benchmarks-"
                         "smoke step skips decode_e2e there because the "
                         "dedicated decode-smoke step already runs it "
                         "(every section still runs exactly once per CI job)")
    args = ap.parse_args(argv)
    global _SMOKE
    _SMOKE = args.smoke
    sections = [paper_rows, micro_rows, lm_rows, fused_rows, shared_rows,
                shard_rows, pr4_rows, decode_e2e_rows, decode_e2e_pr8_rows,
                resilience_rows, traffic_rows, drift_rows, roofline_rows]
    if args.only:
        sections = [s for s in sections
                    if s.__name__.startswith(args.only)]
        if not sections:
            ap.error(f"--only {args.only!r} matches no section")
    if args.skip:
        sections = [s for s in sections
                    if not s.__name__.startswith(args.skip)]
    if args.smoke:
        outdir = tempfile.mkdtemp(prefix="bench-smoke-")
        os.environ.setdefault("REPRO_PCILT_TUNE_CACHE",
                              os.path.join(outdir, "tiles.json"))
        print(f"# smoke mode: JSON payloads under {outdir}", file=sys.stderr)
        for i, fn in enumerate(sections):
            if "bench_json" in fn.__code__.co_varnames:
                sections[i] = functools.partial(
                    fn, bench_json=os.path.join(
                        outdir, fn.__defaults__[0]))
    print("name,us_per_call,derived")
    failures = 0
    for section in sections:
        try:
            section_rows = section()
        except Exception as e:  # noqa: BLE001 — one section must not kill the rest
            fn = section.func if hasattr(section, "func") else section
            reason = f"{type(e).__name__}: {e}".splitlines()[0][:160]
            section_rows = [(f"{fn.__name__}.error", 0.0,
                             _SKIP_PREFIX + reason)]
            failures += 1
        for name, val, derived in section_rows:
            if isinstance(derived, str) and derived.startswith(_SKIP_PREFIX):
                failures += 1
            print(f"{name},{val},{derived}")
    if args.smoke and failures:
        sys.exit(1)  # the CI smoke run must fail loudly, not rot silently


if __name__ == "__main__":
    main()
