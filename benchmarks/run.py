"""Benchmark harness.  Prints ``name,us_per_call,derived`` CSV.

Sections:
  paper.*    — the paper's quantitative claims (benchmarks/paper_claims.py);
               derived = paper's own value where it states one.
  micro.*    — CPU microbenchmarks of the PCILT fetch paths vs direct
               multiplication at several shapes/cardinalities.
  lm.*       — PCILT decode-projection table memory for the assigned archs
               (the paper's memory feasibility analysis applied to the zoo).
  fused.*    — host-packed vs fused Pallas pipelines (quantize→pack→fetch in
               VMEM, repro.kernels.pcilt_fused) at the paper's 5x5-conv shape
               and the LM decode-GEMV regime; the fused path is autotuned
               once through the persistent tile lookup table first.  Results
               are also written to BENCH_pr1.json at the repo root to seed
               the per-PR perf trajectory.
  roofline.* — summary terms per hillclimbed cell (full table:
               ``python -m benchmarks.roofline``).
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _timeit(fn, reps=5, warmup=2):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6  # us


def paper_rows():
    from benchmarks.paper_claims import all_claims

    out = []
    for name, ours, paper, _ in all_claims():
        out.append((f"paper.{name}", ours, paper if paper is not None else ""))
    return out


def micro_rows():
    import jax
    import jax.numpy as jnp
    from repro.core import (QuantSpec, calibrate, build_grouped_tables,
                            pcilt_linear, quantize, dequantize)

    rows = []
    rng = np.random.default_rng(0)
    for (bits, group, n, out, batch) in [(1, 8, 2048, 256, 256),
                                         (2, 4, 2048, 256, 256),
                                         (4, 2, 1024, 256, 256)]:
        spec = QuantSpec(bits)
        x = jnp.asarray(np.abs(rng.normal(size=(batch, n))), jnp.float32)
        w = jnp.asarray(rng.normal(size=(n, out)), jnp.float32)
        s = calibrate(x, spec)
        T = build_grouped_tables(w, spec, s, group)
        xq = dequantize(quantize(x, spec, s), spec, s)

        dm = jax.jit(lambda xq, w: xq @ w)
        ga = jax.jit(lambda x, T: pcilt_linear(x, T, spec, s, group, path="gather"))
        oh = jax.jit(lambda x, T: pcilt_linear(x, T, spec, s, group, path="onehot"))
        dm(xq, w).block_until_ready()
        t_dm = _timeit(lambda: dm(xq, w).block_until_ready())
        t_ga = _timeit(lambda: ga(x, T).block_until_ready())
        t_oh = _timeit(lambda: oh(x, T).block_until_ready())
        tag = f"b{bits}g{group}_{n}x{out}"
        rows.append((f"micro.dm_{tag}", t_dm, ""))
        rows.append((f"micro.lut_gather_{tag}", t_ga, f"{t_dm/t_ga:.2f}x vs dm"))
        rows.append((f"micro.lut_onehot_{tag}", t_oh, f"{t_dm/t_oh:.2f}x vs dm"))
    return rows


def lm_rows():
    from repro.configs import ARCHS, get_config
    from repro.core.serving import mlp_table_bytes

    rows = []
    for arch in ARCHS:
        cfg = get_config(arch)
        if not cfg.d_ff:
            continue
        b = mlp_table_bytes(cfg.d_model, cfg.d_ff, act_bits=4, group=2)
        rows.append((f"lm.mlp_tables_{arch}", b / 2**20,
                     "MiB/layer @INT4 g=2 — why ext.3 sharing matters"))
    return rows


def fused_rows(bench_json: str = "BENCH_pr1.json"):
    import jax
    import jax.numpy as jnp
    from repro.core import QuantSpec, calibrate, build_grouped_tables, pcilt_linear
    from repro.core.lut_layers import pcilt_conv2d
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    rows = []
    speedups = {}

    # --- LM decode-GEMV regime: batch-starved projection [n -> O] ---------
    bits, group = 2, 2
    spec = QuantSpec(bits)
    B, n, O = 8, 1024, 1024
    x = jnp.asarray(np.abs(rng.normal(size=(B, n))), jnp.float32)
    w = jnp.asarray(rng.normal(size=(n, O)), jnp.float32)
    s = calibrate(x, spec)
    T = build_grouped_tables(w, spec, s, group)
    # tune-once-and-record through the persistent lookup table; the jitted
    # dispatch below then hits the cache at trace time (zero-cost lookup).
    ops.pcilt_fused_gemv(x, T, spec, s, group, autotune=True)
    host = jax.jit(lambda x: pcilt_linear(x, T, spec, s, group, path="kernel"))
    fused = jax.jit(lambda x: pcilt_linear(x, T, spec, s, group, path="fused"))
    host(x).block_until_ready()
    fused(x).block_until_ready()
    t_host = _timeit(lambda: host(x).block_until_ready())
    t_fused = _timeit(lambda: fused(x).block_until_ready())
    speedups["decode_gemv"] = t_host / t_fused
    tag = f"decode_b{bits}g{group}_{n}x{O}"
    rows.append((f"fused.{tag}_hostpacked", t_host, ""))
    rows.append((f"fused.{tag}_fused", t_fused,
                 f"{t_host / t_fused:.2f}x vs host-packed kernel"))

    Tb = T.astype(jnp.bfloat16)
    ops.pcilt_fused_gemv(x, Tb, spec, s, group, autotune=True)
    fused_b = jax.jit(lambda x: pcilt_linear(x, Tb, spec, s, group, path="fused"))
    fused_b(x).block_until_ready()
    t_fused_b = _timeit(lambda: fused_b(x).block_until_ready())
    speedups["decode_gemv_bf16"] = t_host / t_fused_b
    rows.append((f"fused.{tag}_fused_bf16tab", t_fused_b,
                 f"{t_host / t_fused_b:.2f}x vs host-packed kernel"))

    # --- the paper's conv regime: 5x5 filter, small image, low-bit codes --
    B, H, W, C, kh, kw, Co = 2, 14, 14, 8, 5, 5, 16
    xc = jnp.asarray(np.abs(rng.normal(size=(B, H, W, C))), jnp.float32)
    f = jnp.asarray(rng.normal(size=(kh, kw, C, Co)), jnp.float32)
    sc = calibrate(xc, spec)
    nf = kh * kw * C
    Tc = build_grouped_tables(f.reshape(nf, Co), spec, sc, group)
    ops.pcilt_fused_conv2d(xc, Tc, spec, sc, group, kh, kw, autotune=True)
    hostc = jax.jit(lambda x: pcilt_conv2d(x, f, spec, sc, group, path="kernel"))
    fusedc = jax.jit(lambda x: pcilt_conv2d(x, f, spec, sc, group, path="fused"))
    hostc(xc).block_until_ready()
    fusedc(xc).block_until_ready()
    t_hostc = _timeit(lambda: hostc(xc).block_until_ready())
    t_fusedc = _timeit(lambda: fusedc(xc).block_until_ready())
    speedups["conv5x5"] = t_hostc / t_fusedc
    tagc = f"conv5x5_b{bits}g{group}_{C}to{Co}"
    rows.append((f"fused.{tagc}_hostpacked", t_hostc, ""))
    rows.append((f"fused.{tagc}_fused", t_fusedc,
                 f"{t_hostc / t_fusedc:.2f}x vs host-packed kernel"))

    if bench_json:
        payload = {
            "pr": 1,
            "backend": jax.default_backend(),
            "timing": "interpret-mode CPU" if jax.default_backend() != "tpu"
                      else "compiled TPU",
            "target_min_speedup": 1.3,
            "speedup": {k: round(v, 3) for k, v in speedups.items()},
            "rows": [
                {"name": name, "us_per_call": round(us, 2), "derived": derived}
                for name, us, derived in rows
            ],
        }
        with open(os.path.join(REPO_ROOT, bench_json), "w") as fp:
            json.dump(payload, fp, indent=1)
    return rows


def roofline_rows():
    import glob
    import json
    import os
    from benchmarks.roofline import terms, DRYRUN_DIR

    rows = []
    targets = [
        ("llama4-maverick-400b-a17b", "train_4k", "pod16x16"),
        ("qwen3-0.6b", "train_4k", "pod16x16"),
        ("granite-moe-3b-a800m", "decode_32k", "pod16x16"),
    ]
    for arch, shape, mesh in targets:
        safe = arch.replace(".", "_")
        p = os.path.join(DRYRUN_DIR, f"{safe}__{shape}__{mesh}.json")
        if not os.path.exists(p):
            continue
        c = json.load(open(p))
        if c["status"] != "ok":
            continue
        t_c, t_m, t_k, dom, frac, useful = terms(c)
        rows.append((f"roofline.{arch}.{shape}.step_s",
                     (max(t_c, t_m, t_k)) * 1e6,
                     f"dom={dom} frac={frac:.3f} useful={useful:.3f}"))
    return rows


def main() -> None:
    print("name,us_per_call,derived")
    for section in (paper_rows, micro_rows, lm_rows, fused_rows, roofline_rows):
        for name, val, derived in section():
            print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
