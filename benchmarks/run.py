"""Benchmark harness.  Prints ``name,us_per_call,derived`` CSV.

Sections:
  paper.*    — the paper's quantitative claims (benchmarks/paper_claims.py);
               derived = paper's own value where it states one.
  micro.*    — CPU microbenchmarks of the PCILT fetch paths vs direct
               multiplication at several shapes/cardinalities.
  lm.*       — PCILT decode-projection table memory for the assigned archs
               (the paper's memory feasibility analysis applied to the zoo).
  roofline.* — summary terms per hillclimbed cell (full table:
               ``python -m benchmarks.roofline``).
"""

from __future__ import annotations

import time

import numpy as np


def _timeit(fn, reps=5, warmup=2):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6  # us


def paper_rows():
    from benchmarks.paper_claims import all_claims

    out = []
    for name, ours, paper, _ in all_claims():
        out.append((f"paper.{name}", ours, paper if paper is not None else ""))
    return out


def micro_rows():
    import jax
    import jax.numpy as jnp
    from repro.core import (QuantSpec, calibrate, build_grouped_tables,
                            pcilt_linear, quantize, dequantize)

    rows = []
    rng = np.random.default_rng(0)
    for (bits, group, n, out, batch) in [(1, 8, 2048, 256, 256),
                                         (2, 4, 2048, 256, 256),
                                         (4, 2, 1024, 256, 256)]:
        spec = QuantSpec(bits)
        x = jnp.asarray(np.abs(rng.normal(size=(batch, n))), jnp.float32)
        w = jnp.asarray(rng.normal(size=(n, out)), jnp.float32)
        s = calibrate(x, spec)
        T = build_grouped_tables(w, spec, s, group)
        xq = dequantize(quantize(x, spec, s), spec, s)

        dm = jax.jit(lambda xq, w: xq @ w)
        ga = jax.jit(lambda x, T: pcilt_linear(x, T, spec, s, group, path="gather"))
        oh = jax.jit(lambda x, T: pcilt_linear(x, T, spec, s, group, path="onehot"))
        dm(xq, w).block_until_ready()
        t_dm = _timeit(lambda: dm(xq, w).block_until_ready())
        t_ga = _timeit(lambda: ga(x, T).block_until_ready())
        t_oh = _timeit(lambda: oh(x, T).block_until_ready())
        tag = f"b{bits}g{group}_{n}x{out}"
        rows.append((f"micro.dm_{tag}", t_dm, ""))
        rows.append((f"micro.lut_gather_{tag}", t_ga, f"{t_dm/t_ga:.2f}x vs dm"))
        rows.append((f"micro.lut_onehot_{tag}", t_oh, f"{t_dm/t_oh:.2f}x vs dm"))
    return rows


def lm_rows():
    from repro.configs import ARCHS, get_config
    from repro.core.serving import mlp_table_bytes

    rows = []
    for arch in ARCHS:
        cfg = get_config(arch)
        if not cfg.d_ff:
            continue
        b = mlp_table_bytes(cfg.d_model, cfg.d_ff, act_bits=4, group=2)
        rows.append((f"lm.mlp_tables_{arch}", b / 2**20,
                     "MiB/layer @INT4 g=2 — why ext.3 sharing matters"))
    return rows


def roofline_rows():
    import glob
    import json
    import os
    from benchmarks.roofline import terms, DRYRUN_DIR

    rows = []
    targets = [
        ("llama4-maverick-400b-a17b", "train_4k", "pod16x16"),
        ("qwen3-0.6b", "train_4k", "pod16x16"),
        ("granite-moe-3b-a800m", "decode_32k", "pod16x16"),
    ]
    for arch, shape, mesh in targets:
        safe = arch.replace(".", "_")
        p = os.path.join(DRYRUN_DIR, f"{safe}__{shape}__{mesh}.json")
        if not os.path.exists(p):
            continue
        c = json.load(open(p))
        if c["status"] != "ok":
            continue
        t_c, t_m, t_k, dom, frac, useful = terms(c)
        rows.append((f"roofline.{arch}.{shape}.step_s",
                     (max(t_c, t_m, t_k)) * 1e6,
                     f"dom={dom} frac={frac:.3f} useful={useful:.3f}"))
    return rows


def main() -> None:
    print("name,us_per_call,derived")
    for section in (paper_rows, micro_rows, lm_rows, roofline_rows):
        for name, val, derived in section():
            print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
