"""Roofline report: reads the dry-run cell JSONs and emits the per-(arch ×
shape × mesh) three-term roofline table (EXPERIMENTS.md §Roofline).

Hardware constants (v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

  compute    = flops_per_device / 197e12          [s]
  memory     = hbm_traffic_est_per_device / 819e9 [s]  (write+read proxy)
  collective = collective_bytes_per_device / 50e9 [s]

Dominant term = the bottleneck; roofline fraction = compute / max(all) —
i.e. how much of the step is MXU-limited rather than stalled on HBM or ICI.
Useful ratio = MODEL_FLOPS / (HLO flops × chips) — remat/padding/dispatch
overhead visibility.
"""

from __future__ import annotations

import glob
import json
import os
import sys

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_cells(mesh: str = None):
    cells = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        c = json.load(open(p))
        if mesh and c.get("mesh") != mesh:
            continue
        cells.append(c)
    return cells


def terms(c):
    """Returns (t_compute, t_memory, t_collective, dominant, frac, useful).

    ``frac`` = dominant / (sum of terms): how close a perfectly-overlapped
    step runs to its single-resource roofline (1.0 = one resource binds, the
    others ride under it).  For train cells the *compute* term should
    dominate; for decode, *memory* domination IS the roofline.  ``mfu_bound``
    (= compute/max) is reported separately in the summary.
    """
    f = c["cost"]["flops_per_device"]
    b = c["cost"]["bytes_traffic_est_per_device"]
    k = c["collective_bytes_per_device"]
    t_c = f / PEAK_FLOPS
    t_m = b / HBM_BW
    t_k = k / ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_k, "collective"))
    tot = t_c + t_m + t_k
    frac = dom[0] / tot if tot > 0 else 0.0
    useful = c["model_flops_global"] / max(f * c["n_chips"], 1.0)
    return t_c, t_m, t_k, dom[1], frac, useful


def mfu_bound(c) -> float:
    t_c, t_m, t_k, *_ = terms(c)
    m = max(t_c, t_m, t_k)
    return t_c / m if m > 0 else 0.0


def table(cells, fmt="md"):
    hdr = ["arch", "shape", "mesh", "status", "mem GiB/dev", "compute s",
           "memory s", "collective s", "dominant", "roofline frac",
           "mfu bound", "useful ratio"]
    rows = []
    for c in cells:
        if c["status"] == "skipped":
            rows.append([c["arch"], c["shape"], c["mesh"], "skip(§7)",
                         "-", "-", "-", "-", "-", "-", "-", "-"])
            continue
        if c["status"] != "ok":
            rows.append([c["arch"], c["shape"], c["mesh"], "ERROR"] + ["-"] * 8)
            continue
        t_c, t_m, t_k, dom, frac, useful = terms(c)
        rows.append([
            c["arch"], c["shape"], c["mesh"], "ok",
            f"{c['memory']['total_nonalias_bytes']/2**30:.2f}",
            f"{t_c:.3f}", f"{t_m:.3f}", f"{t_k:.3f}", dom,
            f"{frac:.3f}", f"{mfu_bound(c):.3f}", f"{useful:.3f}",
        ])
    if fmt == "md":
        out = ["| " + " | ".join(hdr) + " |",
               "|" + "---|" * len(hdr)]
        out += ["| " + " | ".join(str(x) for x in r) + " |" for r in rows]
        return "\n".join(out)
    return "\n".join(",".join(str(x) for x in r) for r in [hdr] + rows)


def main():
    mesh = None
    fmt = "md"
    args = sys.argv[1:]
    if "--csv" in args:
        fmt = "csv"
    if "--mesh" in args:
        mesh = args[args.index("--mesh") + 1]
    cells = load_cells(mesh)
    if not cells:
        print("no dry-run cells found — run: python -m repro.launch.dryrun --all")
        return 1
    print(table(cells, fmt))
    ok = [c for c in cells if c["status"] == "ok"]
    if ok:
        trains = [c for c in ok if c["shape"].startswith("train")]
        worst = min(trains or ok, key=mfu_bound)
        coll = max(ok, key=lambda c: terms(c)[2])
        print(f"\nworst train mfu bound:  {worst['arch']} {worst['shape']} "
              f"{worst['mesh']} ({mfu_bound(worst):.3f})")
        print(f"most collective-bound:  {coll['arch']} {coll['shape']} "
              f"{coll['mesh']} ({terms(coll)[2]:.2f}s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
